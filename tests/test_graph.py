"""Intervention-graph IR: construction, validation, serialization.

Includes hypothesis property tests on the system's core invariants:
  * serialization roundtrip is exact for arbitrary op graphs,
  * node ids are a topological order (acyclicity by construction),
  * the paper's setter rule rejects future-dependent setters.
"""
import json

import numpy as np
import pytest
from tests._prop import given, settings, st

from repro.core.graph import (
    GraphValidationError,
    InterventionGraph,
    Node,
    Ref,
)
from repro.core.serialize import (
    decode_value,
    dumps,
    encode_value,
    graph_from_json,
    graph_to_json,
    loads,
    structural_key,
)

ORDER = [("a", None), ("b", 0), ("b", 1), ("c", None)]


def test_add_and_refs():
    g = InterventionGraph()
    n0 = g.add("tap_get", site="a")
    n1 = g.add("mul", Ref(n0.id), 2.0)
    n2 = g.add("save", Ref(n1.id))
    g.mark_saved("out", n2)
    assert [n.op for n in g.nodes] == ["tap_get", "mul", "save"]
    assert list(g.nodes[1].refs())[0].node_id == 0
    g.validate(ORDER)


def test_forward_reference_rejected():
    g = InterventionGraph()
    with pytest.raises(GraphValidationError):
        g.add("mul", Ref(5), 2.0)


def test_unknown_site_rejected():
    g = InterventionGraph()
    g.add("tap_get", site="nope")
    with pytest.raises(GraphValidationError):
        g.validate(ORDER)


def test_setter_rule():
    """Paper §3.1: no directed path from a later value into an earlier set."""
    g = InterventionGraph()
    late = g.add("tap_get", site="c")
    val = g.add("mul", Ref(late.id), 2.0)
    g.add("tap_set", Ref(val.id), site="a")  # set at 'a' from 'c' -> cycle
    with pytest.raises(GraphValidationError):
        g.validate(ORDER)


def test_setter_rule_same_site_ok():
    g = InterventionGraph()
    v = g.add("tap_get", site="b", layer=0)
    val = g.add("mul", Ref(v.id), 2.0)
    g.add("tap_set", Ref(val.id), site="b", layer=0)
    g.validate(ORDER)


def test_listeners():
    g = InterventionGraph()
    a = g.add("tap_get", site="a")
    b = g.add("mul", Ref(a.id), 2.0)
    c = g.add("add", Ref(a.id), Ref(b.id))
    ls = g.listeners()
    assert ls[a.id] == [b.id, c.id]
    assert ls[c.id] == []


# ---------------------------------------------------------------- wire format
def test_roundtrip_values():
    cases = [
        None, True, 1, -2.5, "s", [1, 2], (1, (2, 3)),
        slice(1, None, 2), Ellipsis,
        np.arange(6, dtype=np.float32).reshape(2, 3),
        np.float64(3.5), np.int32(7), np.dtype("bfloat16"),
        {"k": (slice(None), 3)},
    ]
    for v in cases:
        enc = encode_value(v)
        json.dumps(enc)  # must be JSON-clean
        dec = decode_value(json.loads(json.dumps(enc)))
        if isinstance(v, np.ndarray):
            np.testing.assert_array_equal(dec, v)
        else:
            assert dec == v or (v is Ellipsis and dec is Ellipsis)


def test_graph_roundtrip():
    g = InterventionGraph()
    t = g.add("tap_get", site="b", layer=1)
    c = g.add("constant", np.ones((2, 2), np.float32))
    u = g.add("update_path", Ref(t.id), ((0,) + (slice(1, 3),),), Ref(c.id))
    g.add("tap_set", Ref(u.id), site="b", layer=1)
    s = g.add("save", Ref(t.id))
    g.mark_saved("x", s)
    g.backward_loss = s.id

    g2 = loads(dumps(g))
    assert len(g2) == len(g)
    assert g2.saves == g.saves
    assert g2.backward_loss == g.backward_loss
    for n1, n2 in zip(g.nodes, g2.nodes):
        assert n1.op == n2.op and n1.site == n2.site and n1.layer == n2.layer
    np.testing.assert_array_equal(g2.nodes[1].args[0], np.ones((2, 2)))


def test_structural_key_ignores_constant_values():
    def build(val):
        g = InterventionGraph()
        t = g.add("tap_get", site="a")
        c = g.add("constant", np.full((3,), val, np.float32))
        g.add("add", Ref(t.id), Ref(c.id))
        return g

    assert structural_key(build(1.0)) == structural_key(build(9.0))
    # but different shapes differ
    g3 = InterventionGraph()
    t = g3.add("tap_get", site="a")
    c = g3.add("constant", np.zeros((4,), np.float32))
    g3.add("add", Ref(t.id), Ref(c.id))
    assert structural_key(build(1.0)) != structural_key(g3)


def test_tampered_wire_rejected():
    g = InterventionGraph()
    g.add("tap_get", site="a")
    payload = graph_to_json(g)
    payload["nodes"][0]["id"] = 5  # non-dense ids
    with pytest.raises(ValueError):
        graph_from_json(payload)

    payload = graph_to_json(g)
    payload["version"] = 99
    with pytest.raises(ValueError):
        graph_from_json(payload)


# ------------------------------------------------------------------ property
_ops = st.sampled_from(["add", "mul", "sub", "jnp.maximum", "jnp.minimum"])


@st.composite
def random_graph(draw):
    g = InterventionGraph()
    root = g.add("tap_get", site="a")
    n_nodes = draw(st.integers(1, 25))
    for _ in range(n_nodes):
        choice = draw(st.integers(0, 3))
        if choice == 0:
            g.add("constant", np.float32(draw(st.floats(-5, 5, width=32))))
        else:
            a = Ref(draw(st.integers(0, len(g.nodes) - 1)))
            b = Ref(draw(st.integers(0, len(g.nodes) - 1)))
            g.add(draw(_ops), a, b)
    last = g.add("save", Ref(len(g.nodes) - 1))
    g.mark_saved("out", last)
    return g


@given(random_graph())
@settings(max_examples=50, deadline=None)
def test_property_roundtrip(g):
    g2 = loads(dumps(g))
    assert len(g2) == len(g)
    for n1, n2 in zip(g.nodes, g2.nodes):
        assert n1.op == n2.op
        assert [r.node_id for r in n1.refs()] == [r.node_id for r in n2.refs()]
    assert g2.saves == g.saves


@given(random_graph())
@settings(max_examples=50, deadline=None)
def test_property_topological(g):
    """Every ref points strictly backwards: ids are a topological order."""
    for n in g.nodes:
        for r in n.refs():
            assert r.node_id < n.id


@given(random_graph())
@settings(max_examples=25, deadline=None)
def test_property_schedule_monotone(g):
    """A node's ready index is >= each dependency's ready index."""
    ready = g.schedule([("a", None)])
    for n in g.nodes:
        for r in n.refs():
            assert ready[n.id] >= ready[r.node_id]


def test_bfloat16_array_roundtrip():
    """bf16 activations cross the wire exactly (ml_dtypes-backed)."""
    import jax.numpy as jnp

    arr = np.asarray(jnp.linspace(-3, 3, 24, dtype=jnp.bfloat16).reshape(4, 6))
    dec = decode_value(json.loads(json.dumps(encode_value(arr))))
    assert dec.dtype == arr.dtype
    np.testing.assert_array_equal(dec, arr)


# ----------------------------------------------------- node fingerprints
def _node(op, *args, **kw):
    g = InterventionGraph()
    return g.add(op, *args, **kw)


def test_fingerprint_excludes_step_stamp():
    """The step coordinate is scheduling metadata, not structure — the
    fused planner matches per-step slices across steps."""
    from repro.core.graph import node_fingerprint

    a = _node("tap_get", site="b", layer=1, step=0)
    b = _node("tap_get", site="b", layer=1, step=5)
    assert node_fingerprint(a) == node_fingerprint(b)
    # site/layer ARE structure
    c = _node("tap_get", site="b", layer=2, step=0)
    assert node_fingerprint(a) != node_fingerprint(c)


def test_fingerprint_abstract_constants():
    """abstract_constants collapses a constant's VALUE to (dtype, shape):
    the planner threads differing per-step constants through the scan,
    so values need not match — but specs must."""
    from repro.core.graph import node_fingerprint

    one = _node("constant", np.full((3,), 1.0, np.float32))
    nine = _node("constant", np.full((3,), 9.0, np.float32))
    # concrete: values distinguish
    assert node_fingerprint(one) != node_fingerprint(nine)
    # abstract: same spec, values collapse
    assert node_fingerprint(one, abstract_constants=True) == \
        node_fingerprint(nine, abstract_constants=True)
    # abstract still distinguishes dtype and shape
    wide = _node("constant", np.full((4,), 1.0, np.float32))
    half = _node("constant", np.full((3,), 1.0, np.float16))
    assert node_fingerprint(one, abstract_constants=True) != \
        node_fingerprint(wide, abstract_constants=True)
    assert node_fingerprint(one, abstract_constants=True) != \
        node_fingerprint(half, abstract_constants=True)


def test_fingerprint_array_args_compare_by_content():
    """Raw array args of NON-constant ops always compare by content, even
    under abstract_constants — only ``constant`` nodes are abstracted."""
    from repro.core.graph import node_fingerprint

    g = InterventionGraph()
    g.add("tap_get", site="a")
    x = g.add("add", Ref(0), np.zeros((2,), np.float32))
    g2 = InterventionGraph()
    g2.add("tap_get", site="a")
    y = g2.add("add", Ref(0), np.ones((2,), np.float32))
    assert node_fingerprint(x, abstract_constants=True) != \
        node_fingerprint(y, abstract_constants=True)
    g3 = InterventionGraph()
    g3.add("tap_get", site="a")
    z = g3.add("add", Ref(0), np.zeros((2,), np.float32))
    assert node_fingerprint(x) == node_fingerprint(z)


def test_fingerprint_and_structural_key_exclude_source_meta():
    """Source-line stamps (tracer-captured user code locations) are
    diagnostics payload, not structure: two traces of the same program
    written on different lines must dedupe to one compiled plan."""
    from repro.core.graph import SOURCE_META_KEY, node_fingerprint

    def build(src):
        g = InterventionGraph()
        t = g.add("tap_get", site="a", meta={SOURCE_META_KEY: src})
        g.mark_saved("out", g.add("save", Ref(t.id)))
        return g

    ga, gb = build("nb.py:3: x"), build("other.py:99: y")
    assert node_fingerprint(ga.nodes[0]) == node_fingerprint(gb.nodes[0])
    assert structural_key(ga) == structural_key(gb)
    # any OTHER meta key is structural
    gc = InterventionGraph()
    t = gc.add("tap_get", site="a", meta={"custom": 1})
    gc.mark_saved("out", gc.add("save", Ref(t.id)))
    assert structural_key(ga) != structural_key(gc)
