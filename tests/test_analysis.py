"""Static preflight analyzer (repro.core.analysis).

Covers the four wiring layers and the analysis facts themselves:
  * abstract shape/dtype inference names the offending NODE (with the
    user's source line, captured at trace time) before anything executes;
  * scheduler admission rejects a broken step graph with ZERO model
    forwards spent — the step-time failure classes of test_continuous
    caught statically;
  * merge-plan checking proves co-tenant row disjointness;
  * fusion lint classifies decode steps with machine-readable reasons;
  * dead-node elimination + stop-site inference;
  * cross-invoke rejection carries structured diagnostics;
  * the false-positive contract: graphs the runtime accepts analyze clean.
"""
import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import analysis
from repro.core.analysis import (
    ERROR,
    NOTE,
    AnalysisReport,
    PreflightError,
    check_merge_plan,
    dead_nodes,
    eliminate_dead,
    infer_stop_site,
    lint_fusion,
)
from repro.core.batching import CrossInvokeError, merge_graphs, split_invokes
from repro.core.generation import _step_order
from repro.core.graph import (
    ALL_STEPS,
    GraphValidationError,
    InterventionGraph,
    Ref,
)
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request

REPO = Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def small():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _tokens(cfg, rows=2, seq=6, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, (rows, seq)).astype(np.int32)


# ------------------------------------------------------------ layer 1: tracer
def test_generation_shape_error_named_with_source_line(small):
    """A wrong-shaped steering vector fails at TRACE EXIT with the node,
    the step, and the user's own source line — not mid-decode."""
    cfg, model, params = small
    lm = traced_lm(model, params)
    bad_vec = np.zeros((cfg.d_model + 1,), np.float32)
    with pytest.raises(PreflightError) as ei:
        with lm.generate(_tokens(cfg), max_new_tokens=4) as tr:
            for s in tr.steps(1, 2):
                lm.layers[1].mlp.output += bad_vec  # SHAPE BUG (this line)
            for s in tr.steps():
                lm.logits.save("logits")
    errs = [d for d in ei.value.diagnostics if d.severity == ERROR]
    assert errs, ei.value.diagnostics
    assert any(d.code == "op-shape" for d in errs)
    # the diagnostic points at THIS test file's steering line
    assert any(d.source and "test_analysis.py" in d.source
               and "SHAPE BUG" in d.source for d in errs)


def test_clean_generation_trace_passes_preflight_and_runs(small):
    """False-positive guard at the tracer layer: a correctly-shaped
    steering trace analyzes clean and then actually executes."""
    cfg, model, params = small
    lm = traced_lm(model, params)
    with lm.generate(_tokens(cfg), max_new_tokens=3) as tr:
        for s in tr.steps(1, 2):
            lm.layers[1].mlp.output += 2.0
        for s in tr.steps():
            lm.logits.save("logits")
    assert tr.preflight_report is not None and tr.preflight_report.ok()
    assert np.asarray(tr.result("logits")).shape == (2, 3, cfg.vocab_size)


# -------------------------------------------------- layer 3: admission
def test_admission_rejects_shape_error_with_zero_forwards(small):
    """A statically-broken step graph never reaches the slot loop: the
    ticket fails at admission and the engine runs NO model forwards."""
    cfg, model, params = small
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=7,
                              num_slots=4, slot_max_len=32)
    bad = InterventionGraph()
    t = bad.add("tap_get", site="layers.mlp.output", layer=1, step=1)
    c = bad.add("constant", np.zeros((cfg.d_model + 3,), np.float32))
    u = bad.add("add", Ref(t.id), Ref(c.id), step=1)
    bad.add("tap_set", Ref(u.id), site="layers.mlp.output", layer=1, step=1)
    ticket = sched.submit(Request(graph=bad, batch={"tokens": _tokens(cfg)},
                                  max_new_tokens=3))
    sched.drain()
    assert ticket.error is not None
    assert "preflight rejected" in ticket.error
    assert "op-shape" in ticket.error
    assert engine.stats.compiles == 0      # zero model forwards spent
    assert engine.stats.admissions == 0
    assert engine.stats.generations == 0


def test_admission_clean_step_graph_still_served(small):
    """False-positive guard at admission: a legal steering graph passes
    preflight and decodes normally through the shared loop."""
    cfg, model, params = small
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=7,
                              num_slots=4, slot_max_len=32)
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.mlp.output", layer=1, step=ALL_STEPS)
    u = g.add("add", Ref(t.id), 2.0, step=ALL_STEPS)
    g.add("tap_set", Ref(u.id), site="layers.mlp.output", layer=1,
          step=ALL_STEPS)
    ticket = sched.submit(Request(graph=g, batch={"tokens": _tokens(cfg, 1)},
                                  max_new_tokens=3))
    sched.drain()
    assert ticket.error is None
    assert ticket.result["tokens"].shape == (1, 3)


# ----------------------------------------------------------- merge plans
def test_check_merge_plan_proves_disjointness():
    g1, g2 = InterventionGraph(), InterventionGraph()
    for g in (g1, g2):
        t = g.add("tap_get", site="layers.output", layer=0)
        u = g.add("mul", Ref(t.id), 2.0)
        g.add("tap_set", Ref(u.id), site="layers.output", layer=0)
    # clean: disjoint, in-bounds
    assert not [d for d in check_merge_plan([g1, g2], [2, 3], [0, 2],
                                            num_rows=8)
                if d.severity == ERROR]
    # overlap: tenant 1 starts inside tenant 0's rows
    diags = check_merge_plan([g1, g2], [2, 3], [0, 1], num_rows=8)
    overlap = [d for d in diags if d.code == "row-overlap"]
    assert overlap and overlap[0].severity == ERROR
    assert "layers.output" in overlap[0].message  # both write this site
    # bounds: tenant escapes the slot table
    diags = check_merge_plan([g1, g2], [2, 3], [0, 6], num_rows=8)
    assert any(d.code == "row-bounds" and d.severity == ERROR for d in diags)
    # cross-tenant read/write pairs surface as notes (isolation holds)
    r = InterventionGraph()
    t = r.add("tap_get", site="layers.output", layer=0)
    r.mark_saved("h", r.add("save", Ref(t.id)))
    notes = [d for d in check_merge_plan([g1, r], [2, 2], [0, 2], num_rows=8)
             if d.code == "cross-tenant-read"]
    assert notes and notes[0].severity == NOTE


def test_merge_graphs_rejects_overlapping_starts():
    """merge_graphs with an explicit (overlapping) row plan refuses to
    build the merged graph — the checked-merge-plan contract."""
    g1, g2 = InterventionGraph(), InterventionGraph()
    for g in (g1, g2):
        t = g.add("tap_get", site="logits")
        g.mark_saved("out", g.add("save", Ref(t.id)))
    with pytest.raises(GraphValidationError, match="merge plan rejected"):
        merge_graphs([g1, g2], [2, 2], starts=[0, 1])
    merged = merge_graphs([g1, g2], [2, 2], starts=[0, 2])  # disjoint: fine
    assert merged.graph.nodes and merged.row_slices == [(0, 2), (2, 2)]


# ------------------------------------------------------------ fusion lint
def test_lint_fusion_reasons(small):
    cfg, model, params = small
    sched = _step_order(model.site_schedule("unrolled"))
    g = InterventionGraph()
    # steps 0..1/3: plain steering; step 2 adds a log — still fusable (the
    # compiled body emits via jax.debug.callback) but structurally distinct
    # from step 0, so it fuses only within its own uniform run
    t = g.add("tap_get", site="layers.mlp.output", layer=0, step=ALL_STEPS)
    u = g.add("add", Ref(t.id), 1.0, step=ALL_STEPS)
    g.add("tap_set", Ref(u.id), site="layers.mlp.output", layer=0,
          step=ALL_STEPS)
    o = g.add("tap_get", site="logits", step=2)
    g.add("log", Ref(o.id), step=2)
    verdicts = lint_fusion(g, 4, sched)
    assert [v.fusable for v in verdicts] == [True, True, True, True]
    assert verdicts[2].reason == "non-uniform"
    assert verdicts[0].reason == "ok"


def test_lint_fusion_cross_step_flow():
    g = InterventionGraph()
    a = g.add("tap_get", site="logits", step=0)
    u = g.add("mul", Ref(a.id), 2.0, step=0)
    t = g.add("tap_get", site="layers.output", layer=0, step=2)
    m = g.add("add", Ref(t.id), Ref(u.id), step=2)
    g.add("tap_set", Ref(m.id), site="layers.output", layer=0, step=2)
    verdicts = lint_fusion(g, 3)
    assert not verdicts[0].fusable and verdicts[0].reason == "cross-step-flow"


# ----------------------------------------------------- dead nodes / stop
def test_dead_nodes_and_elimination(small):
    cfg, model, params = small
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=1)
    live = g.add("mul", Ref(t.id), 2.0)
    g.mark_saved("x", g.add("save", Ref(live.id)))
    d1 = g.add("add", Ref(t.id), 1.0)      # dead chain
    g.add("abs", Ref(d1.id))               # dead
    dead = dead_nodes(g)
    assert set(dead) == {d1.id, d1.id + 1}
    out, idmap = eliminate_dead(g)
    assert len(out.nodes) == 3 and "x" in out.saves
    # analyzer surfaces dead compute as notes, not errors
    report = analysis.analyze(g)
    assert report.ok()
    assert {d.node for d in report.diagnostics if d.code == "dead-node"} == \
        set(dead)


def test_infer_stop_site(small):
    cfg, model, params = small
    schedule = model.site_schedule("unrolled")
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=1)
    g.mark_saved("h", g.add("save", Ref(t.id)))
    stop = infer_stop_site(g, schedule)
    order = list(schedule.order)
    assert stop is not None and order[stop] == ("layers.output", 1)
    # a logits read needs the whole forward
    g.mark_saved("o", g.add("save", Ref(g.add("tap_get", site="logits").id)))
    assert infer_stop_site(g, schedule) == len(order) - 1


# ---------------------------------------------------------- cross-invoke
def test_cross_invoke_error_carries_diagnostics():
    g = InterventionGraph()
    a = g.add("tap_get", site="layers.output", layer=0, invoke=0)
    b = g.add("tap_get", site="layers.output", layer=0, invoke=1)
    m = g.add("add", Ref(a.id), Ref(b.id), invoke=1)
    g.mark_saved("out", g.add("save", Ref(m.id), invoke=1))
    with pytest.raises(ValueError, match="cross-invoke") as ei:
        split_invokes(g, 2)
    err = ei.value
    assert isinstance(err, CrossInvokeError)
    assert err.diagnostics and all(d.code == "cross-invoke"
                                   for d in err.diagnostics)
    msg = str(err)
    assert "invoke 0" in msg and "invoke 1" in msg  # both indices named
    assert "out" in msg                             # the fed save


# ----------------------------------------------------------- env plumbing
def test_preflight_mode_env(monkeypatch):
    monkeypatch.delenv("REPRO_PREFLIGHT", raising=False)
    assert analysis.preflight_mode() == "enforce"
    monkeypatch.setenv("REPRO_PREFLIGHT", "warn")
    assert analysis.preflight_mode() == "warn"
    monkeypatch.setenv("REPRO_PREFLIGHT", "off")
    assert analysis.preflight_mode() == "off"
    monkeypatch.setenv("REPRO_PREFLIGHT", "nonsense")
    assert analysis.preflight_mode() == "enforce"
    report = AnalysisReport()
    report.diagnostics.append(analysis.Diagnostic("x", ERROR, "boom"))
    assert report.enforce("warn") is report          # warn never raises
    with pytest.raises(PreflightError):
        report.enforce("enforce")


# --------------------------------------------------------------- CLI lint
def test_lint_graph_cli_all_examples():
    """The repo's own example graphs must lint clean (shape-aware, built
    against an abstract weightless model), and the ``--summary`` reason
    table must show the eager islands gone: no fusion verdict anywhere
    carries a "log", "grad", or "scan-cross-layer" reason — the
    harvest-mold interpreter compiles all three."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "lint_graph.py"),
         "--all-examples", "--summary"],
        capture_output=True, text=True, timeout=600,
        cwd=REPO, env={**__import__("os").environ,
                       "PYTHONPATH": str(REPO / "src")},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "FAILED" not in proc.stdout
    assert "examples/steered_generation" in proc.stdout
    summary = json.loads(proc.stdout.strip().splitlines()[-1])
    assert summary["graphs"], "expected per-graph fusion reason counts"
    for retired in ("log", "grad", "scan-cross-layer"):
        assert retired not in summary["total"], summary["total"]
    # the island workloads themselves must be fully fusable
    for label in ("benchmarks/islands:log", "benchmarks/islands:grad",
                  "benchmarks/islands:cross_layer"):
        assert label in summary["graphs"], sorted(summary["graphs"])
