"""NDIF serving stack: server, client, schedulers, security, sessions."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import InterventionGraph, Ref
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import (
    CoTenantScheduler,
    LoopbackTransport,
    NDIFClient,
    NDIFServer,
    Request,
)


@pytest.fixture(scope="module")
def hosted():
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host("paper-gpt-small", model, params, policy="sequential")
    transport = LoopbackTransport(server.handle)
    client = NDIFClient(transport, "paper-gpt-small")
    toks = np.asarray(
        jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    )
    return cfg, model, params, server, transport, client, toks


def test_remote_equals_local(hosted):
    cfg, model, params, server, transport, client, toks = hosted
    lm_remote = traced_lm(model, None, backend=client)
    with lm_remote.trace(toks, remote=True):
        lm_remote.layers[3].output[1, 4, :] = lm_remote.layers[3].output[0, 2, :]
        out_r = lm_remote.output.save("out")
    lm_local = traced_lm(model, params)
    with lm_local.trace(jnp.asarray(toks)):
        lm_local.layers[3].output[1, 4, :] = lm_local.layers[3].output[0, 2, :]
        out_l = lm_local.output.save("out")
    np.testing.assert_allclose(np.asarray(out_r.value),
                               np.asarray(out_l.value), rtol=1e-4, atol=1e-4)


def test_server_side_metric_is_small_on_wire(hosted):
    """Fig. 6c: returning a metric beats returning hidden states."""
    cfg, model, params, server, transport, client, toks = hosted
    lm = traced_lm(model, None, backend=client)

    b0 = (transport.stats.bytes_sent, transport.stats.bytes_received)
    with lm.trace(toks, remote=True):
        logits = lm.output
        (logits[:, -1, 7] - logits[:, -1, 3]).save("logit_diff")
    small = transport.stats.bytes_received - b0[1]

    b1 = transport.stats.bytes_received
    hidden = client.hidden_states(toks)
    big = transport.stats.bytes_received - b1
    assert hidden.shape == (2, 12, cfg.d_model)
    assert big > 50 * small, (big, small)


def test_unknown_model_rejected(hosted):
    cfg, model, params, server, transport, client, toks = hosted
    bad = NDIFClient(transport, "not-hosted")
    with pytest.raises(RuntimeError, match="not hosted"):
        bad.hidden_states(toks)


def test_unregistered_op_rejected(hosted):
    """Safe co-tenancy: ops outside the registry never execute."""
    cfg, model, params, server, transport, client, toks = hosted
    g = InterventionGraph()
    t = g.add("tap_get", site="logits")
    g.nodes.append(
        type(g.nodes[0])(id=1, op="os.system", args=(Ref(0),), kwargs={})
    )
    from repro.core.serialize import graph_to_json

    payload = json.dumps({
        "kind": "trace", "model": "paper-gpt-small",
        "graph": graph_to_json(g),
        "batch": {"tokens": {"__array__": {
            "dtype": "int32", "shape": [1, 4],
            "b64": __import__("base64").b64encode(
                np.zeros((1, 4), np.int32).tobytes()).decode(),
        }}},
    }).encode()
    reply = json.loads(server.handle(payload).decode())
    assert not reply["ok"]
    assert "not in the server op registry" in reply["error"]


def test_weights_never_cross_the_wire(hosted):
    cfg, model, params, server, transport, client, toks = hosted
    lm = traced_lm(model, None, backend=client)
    sent0 = transport.stats.bytes_sent
    with lm.trace(toks, remote=True):
        lm.layers[0].output.save("acts")
    sent = transport.stats.bytes_sent - sent0
    # request = graph + tokens; must be far smaller than the params blob
    n_param_bytes = sum(
        np.asarray(x).nbytes for x in jax.tree.leaves(params)
    )
    assert sent < n_param_bytes / 100


def test_session_single_request(hosted):
    cfg, model, params, server, transport, client, toks = hosted
    lm = traced_lm(model, None, backend=client)
    req0 = transport.stats.requests
    with lm.session(remote=True, backend=client) as sess:
        with sess.trace(toks) as t1:
            a = lm.layers[1].output.save("a")
        with sess.trace(toks) as t2:
            b = lm.layers[2].output.save("b")
    assert transport.stats.requests - req0 == 1  # N traces, ONE request
    assert np.asarray(t1.result("a")).shape == (2, 12, cfg.d_model)
    assert np.asarray(t2.result("b")).shape == (2, 12, cfg.d_model)


def test_generate_api(hosted):
    cfg, model, params, server, transport, client, toks = hosted
    res = client.generate(toks, max_new_tokens=3)
    assert res["tokens"].shape == (2, 3)


# ------------------------------------------------------------- schedulers
def _layer_req(cfg, layer, rows, seq=10, seed=0):
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=layer)
    s = g.add("save", Ref(t.id))
    g.mark_saved("acts", s)
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (rows, seq)).astype(np.int32)
    return Request(graph=g, batch={"tokens": toks})


def test_parallel_cotenancy_merges(hosted):
    cfg, model, params, *_ = hosted
    from repro.serving.engine import InferenceEngine

    engine = InferenceEngine(model, params, name="t")
    sched = CoTenantScheduler(engine, policy="parallel", max_batch_rows=16)
    tickets = [sched.submit(_layer_req(cfg, i % 4, rows=1 + i % 2, seed=i))
               for i in range(5)]
    sched.drain()
    assert engine.stats.executions == 1  # ONE merged forward
    for i, t in enumerate(tickets):
        assert t.error is None
        assert t.result["acts"].shape[0] == 1 + i % 2


def test_sequential_cotenancy_runs_n(hosted):
    cfg, model, params, *_ = hosted
    from repro.serving.engine import InferenceEngine

    engine = InferenceEngine(model, params, name="t")
    sched = CoTenantScheduler(engine, policy="sequential")
    for i in range(3):
        sched.submit(_layer_req(cfg, 0, rows=1, seed=i))
    done = sched.drain()
    assert engine.stats.executions == 3
    assert all(t.error is None for t in done)


def test_engine_compile_cache(hosted):
    """Same structural graph + shapes, different constants: one compile."""
    cfg, model, params, *_ = hosted
    from repro.serving.engine import InferenceEngine

    engine = InferenceEngine(model, params, name="t")
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    for val in (0.0, 1.0, 2.0):
        g = InterventionGraph()
        t = g.add("tap_get", site="layers.output", layer=1)
        c = g.add("constant", np.full((cfg.d_model,), val, np.float32))
        u = g.add("add", Ref(t.id), Ref(c.id))
        g.add("tap_set", Ref(u.id), site="layers.output", layer=1)
        s = g.add("save", Ref(t.id))
        g.mark_saved("x", s)
        engine.execute(g, {"tokens": toks})
    assert engine.stats.compiles == 1
    assert engine.stats.cache_hits == 2


def test_scheduler_survives_bad_request(hosted):
    cfg, model, params, *_ = hosted
    from repro.serving.engine import InferenceEngine

    engine = InferenceEngine(model, params, name="t")
    sched = CoTenantScheduler(engine, policy="sequential")
    bad = InterventionGraph()
    bad.add("tap_get", site="never-a-site")
    t1 = sched.submit(Request(graph=bad, batch={
        "tokens": np.zeros((1, 4), np.int32)}))
    t2 = sched.submit(_layer_req(cfg, 0, 1))
    sched.drain()
    assert t1.error is not None
    assert t2.error is None and t2.result is not None


def test_remote_lora_training(hosted):
    """Paper Code Example 5: a LoRA adapter expressed AS an intervention
    graph, trained server-side; only params + losses return."""
    from repro.serving.remote_train import lora_graph

    cfg, model, params, server, transport, client, toks = hosted
    g, init = lora_graph(layer=2, d_model=cfg.d_model, rank=4,
                         vocab_size=cfg.vocab_size, alpha=2.0)
    labels = np.roll(toks, -1, axis=1)
    res = client.train_module(
        g, {"tokens": toks}, trainable=init,
        fixed_inputs={"labels": labels}, steps=15, lr=5e-3,
    )
    assert res["losses"][-1] < res["losses"][0]
    assert res["params"]["WA"].shape == (cfg.d_model, 4)
    assert np.abs(res["params"]["WB"]).sum() > 0  # actually trained


def test_remote_train_rejects_bad_loss(hosted):
    from repro.serving.remote_train import lora_graph

    cfg, model, params, server, transport, client, toks = hosted
    g, init = lora_graph(layer=0, d_model=cfg.d_model, rank=2,
                         vocab_size=cfg.vocab_size)
    with pytest.raises(RuntimeError, match="nope"):
        client.train_module(g, {"tokens": toks}, trainable=init,
                            fixed_inputs={"labels": toks}, loss="nope",
                            steps=1)


def test_mla_model_serving_roundtrip():
    """The absorbed-MLA decode path serves correctly end-to-end."""
    cfg = R.get_config("minicpm3-4b", reduced=True)
    model = R.build_model("minicpm3-4b", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params)
    client = NDIFClient(LoopbackTransport(server.handle), cfg.name)
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 6)).astype(np.int32)
    res = client.generate(toks, max_new_tokens=3)
    assert res["tokens"].shape == (2, 3)
    # greedy step-1 equals forward argmax (exercises absorbed decode)
    full = model.forward(params, {"tokens": jnp.asarray(toks)})["logits"]
    np.testing.assert_array_equal(
        res["tokens"][:, 0], np.argmax(np.asarray(full)[:, -1], -1))
    # and the MLA latent is a servable intervention site
    lm = traced_lm(model, None, backend=client)
    with lm.trace(toks, remote=True):
        lat = lm.layers[1].attn.kv_latent.save("lat")
    assert np.asarray(lat.value).shape == (2, 6, cfg.mla.kv_lora_rank)
