"""Paged KV cache: block-table indirection over a shared page pool.

Layers under test:
  * models/paged.py — pool layout, dense-view gather, decode absorb,
    paged row scatter/clear (exercised through the DecodeLoop);
  * DecodeLoop allocator — non-contiguous row placement, lifetime page
    reservation with page-by-page decode growth, out-of-order page reuse,
    all-or-nothing admission with structured deficits;
  * core/analysis — ``check_merge_plan`` over index-array starts,
    ``check_page_plan`` page-soundness proofs;
  * kernels — paged pallas flash attention vs the dense kernel on the
    gathered view (bit-exact, interpret mode);
  * scheduler — capped admission retries with a pages/rows deficit;
  * engine — paged counters in the stats snapshot, zero steady-state
    recompiles across varied-length paged schedules.

Parity bar: a paged loop's tokens are EXACTLY a dense (contiguous) loop's
for every family — the decode gathers pages into the logical layout and
runs the family's unchanged dense step, and masked garbage keys saturate
at NEG_INF exactly, so even float accumulation order is identical.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis
from repro.core.generation import DecodeLoop, SlotAllocationError
from repro.core.graph import InterventionGraph, Ref
from repro.models import registry as R
from repro.models.paged import FIRST_PAGE, PagedKVCache, build_paged_cache
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request

FAMILIES = {
    "paper-gpt-small": "transformer",
    "mamba2-1.3b": "ssm",
    "zamba2-2.7b": "hybrid",
    "seamless-m4t-large-v2": "encdec",
}


@pytest.fixture(scope="module", params=sorted(FAMILIES))
def family(request):
    arch = request.param
    cfg = R.get_config(arch, reduced=True)
    model = R.build_model(arch, cfg)
    params = model.init(jax.random.key(0))
    return arch, cfg, model, params


@pytest.fixture(scope="module")
def gpt():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _batch(cfg, rows, seq, seed):
    rng = np.random.default_rng(seed)
    batch = {"tokens": rng.integers(1, cfg.vocab_size,
                                    (rows, seq)).astype(np.int32)}
    if cfg.arch_type == "audio":
        batch["src_embeds"] = rng.standard_normal(
            (rows, cfg.n_source_frames, cfg.d_model)).astype(np.float32)
    return batch


def _run_schedule(model, params, cfg, *, paged, page_size=8, num_pages=None,
                  mode="unrolled"):
    """An interleaved admit/step/retire schedule; returns tokens per id."""
    loop = DecodeLoop(model, params, 4, 48, mode=mode, paged=paged,
                      page_size=page_size, num_pages=num_pages)
    a = loop.admit(InterventionGraph(), _batch(cfg, 1, 7, 1), 6,
                   request_id="a", pad_to=10)
    b = loop.admit(InterventionGraph(), _batch(cfg, 2, 5, 2), 3,
                   request_id="b", pad_to=10)
    loop.step()
    loop.step()
    c = loop.admit(InterventionGraph(), _batch(cfg, 1, 9, 3), 5,
                   request_id="c", pad_to=10)
    loop.step()  # b retires; its rows AND pages free mid-schedule
    d = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 4), 4,
                   request_id="d", pad_to=10)
    loop.run_to_completion()
    return loop, {sr.request_id: np.asarray(sr.result().tokens)
                  for sr in (a, b, c, d)}


# ------------------------------------------------------------------- parity
def test_paged_matches_dense_all_families(family):
    """The SAME interleaved schedule through a paged loop and a dense loop
    produces exactly the same tokens for every family (the paged decode
    gathers into the logical layout and runs the unchanged dense step)."""
    arch, cfg, model, params = family
    _, dense = _run_schedule(model, params, cfg, paged=False)
    loop, paged = _run_schedule(model, params, cfg, paged=True)
    for k in dense:
        np.testing.assert_array_equal(paged[k], dense[k])
    if FAMILIES[arch] == "ssm":
        # nothing to page: the loop must have fallen back to dense rows
        assert not loop.paged
    else:
        assert loop.paged
        assert isinstance(loop.cache, PagedKVCache)
        # everything retired -> every page is back in the pool
        assert loop.pages_in_use() == 0
        assert loop._reserved_unalloc == 0


def test_paged_saves_match_dense(gpt):
    """Intervention-graph saves ride the paged loop bit-exactly: taps see
    the gathered dense view, so getters/setters are untouched."""
    cfg, model, params = gpt

    def probe():
        g = InterventionGraph()
        for s in range(2):
            t = g.add("tap_get", site="layers.output", layer=1, step=s)
            g.mark_saved(f"acts{s}", g.add("save", Ref(t.id)))
        return g

    outs = []
    for paged in (False, True):
        loop = DecodeLoop(model, params, 3, 32, paged=paged, page_size=8)
        loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 4,
                   request_id="rider", pad_to=8)
        loop.step()
        sr = loop.admit(probe(), _batch(cfg, 1, 7, 1), 3,
                        request_id="probe", pad_to=8)
        loop.run_to_completion()
        outs.append(sr.result())
    for k in outs[0].saves:
        np.testing.assert_array_equal(np.asarray(outs[0].saves[k]),
                                      np.asarray(outs[1].saves[k]))
    np.testing.assert_array_equal(np.asarray(outs[0].tokens),
                                  np.asarray(outs[1].tokens))


# ------------------------------------------------------------ page lifecycle
def test_page_reuse_after_out_of_order_retirement(gpt):
    """Requests retire in a different order than they were admitted; their
    pages return to the pool and are reused by later admissions with no
    stale-key contamination (tokens stay bit-exact vs a dense loop)."""
    cfg, model, params = gpt

    def run(paged):
        loop = DecodeLoop(model, params, 4, 32, paged=paged, page_size=4)
        a = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 8,
                       request_id="a", pad_to=8)
        b = loop.admit(InterventionGraph(), _batch(cfg, 1, 7, 1), 2,
                       request_id="b", pad_to=8)
        c = loop.admit(InterventionGraph(), _batch(cfg, 1, 5, 2), 5,
                       request_id="c", pad_to=8)
        if paged:
            used0 = loop.pages_in_use()
            assert used0 > 0
        loop.step()
        loop.step()  # b (admitted second) retires FIRST
        assert "b" not in {sr.request_id for sr in loop.resident}
        if paged:
            assert loop.pages_in_use() < used0 + 2  # b's pages came back
        # d reuses b's freed pages while a/c still decode on theirs
        d = loop.admit(InterventionGraph(), _batch(cfg, 1, 8, 3), 4,
                       request_id="d", pad_to=8)
        loop.run_to_completion()
        if paged:
            assert loop.pages_in_use() == 0
            assert sorted(loop._free_pages) == list(
                range(FIRST_PAGE, loop.num_pages))
        return {sr.request_id: np.asarray(sr.result().tokens)
                for sr in (a, b, c, d)}

    dense, paged = run(False), run(True)
    for k in dense:
        np.testing.assert_array_equal(paged[k], dense[k])


def test_growth_across_page_boundary_mid_decode(gpt):
    """A request allocated by ACTUAL prompt length grows page-by-page as
    decode crosses block boundaries — from its admission-time reservation,
    so growth can never fail — and the grown pages carry the decode
    bit-exactly."""
    cfg, model, params = gpt
    loop = DecodeLoop(model, params, 2, 32, paged=True, page_size=4)
    # base_pos = 5 -> prefill covers blocks 0..1; decode reaches pos 12
    # -> lifetime need 4 blocks, so TWO growth events must happen
    sr = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 8,
                    request_id="g")
    row = int(sr.rows[0])
    assert sr.page_need[row] == 4
    assert len(sr.pages[row]) == 2  # only the prefill extent is allocated
    assert loop._reserved_unalloc == 2
    used = [loop.pages_in_use()]
    for _ in range(8):
        loop.step()
    used.append(loop.pages_in_use())
    assert not loop.resident
    # dense reference
    ref = DecodeLoop(model, params, 2, 32, paged=False)
    want = ref.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 8,
                     request_id="g")
    ref.run_to_completion()
    np.testing.assert_array_equal(np.asarray(sr.result().tokens),
                                  np.asarray(want.result().tokens))
    assert loop.pages_in_use() == 0 and loop._reserved_unalloc == 0


def test_fused_window_growth_stays_bit_exact(gpt):
    """run_to_completion fuses whole inter-retirement windows into single
    lax.scan dispatches; block tables grown BEFORE each window thread
    through the scan carry, and multi-step windows match stepping."""
    cfg, model, params = gpt

    def run(stepwise):
        loop = DecodeLoop(model, params, 2, 32, mode="scan", paged=True,
                          page_size=4)
        sr = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 10,
                        request_id="w")
        if stepwise:
            while loop.resident:
                loop.step()
        else:
            loop.run_to_completion()
        assert loop.fused_steps > 0
        return np.asarray(sr.result().tokens)

    np.testing.assert_array_equal(run(True), run(False))


def test_noncontiguous_rows_admission(gpt):
    """Row fragmentation no longer rejects admissions: with free rows
    {0, 3} a 2-row request is served by an index-array placement and is
    bit-exact vs a contiguous placement of the same request."""
    cfg, model, params = gpt

    def run(paged):
        loop = DecodeLoop(model, params, 4, 32, paged=paged, page_size=8)
        x = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 1,
                       request_id="x", pad_to=8)
        y = loop.admit(InterventionGraph(), _batch(cfg, 2, 7, 1), 6,
                       request_id="y", pad_to=8)
        z = loop.admit(InterventionGraph(), _batch(cfg, 1, 5, 2), 1,
                       request_id="z", pad_to=8)
        loop.step()  # x and z retire -> free rows are {0, 3}
        assert sorted(loop._free) == [0, 3]
        w = loop.admit(InterventionGraph(), _batch(cfg, 2, 6, 3), 4,
                       request_id="w", pad_to=8)
        assert w.row_list is not None and w.placement == (0, 3)
        assert loop.frag_avoided == 1
        loop.run_to_completion()
        return {sr.request_id: np.asarray(sr.result().tokens)
                for sr in (x, y, z, w)}

    dense, paged = run(False), run(True)
    for k in dense:
        np.testing.assert_array_equal(paged[k], dense[k])
    # contiguous reference for the fragmented request
    ref = DecodeLoop(model, params, 4, 32)
    want = ref.admit(InterventionGraph(), _batch(cfg, 2, 6, 3), 4,
                     request_id="w", pad_to=8)
    ref.run_to_completion()
    np.testing.assert_array_equal(dense["w"], np.asarray(want.result().tokens))


def test_noncontiguous_rows_with_step_graphs(gpt):
    """Intervention graphs on a fragmented placement rewrite through the
    index-array getter/setter path and stay isolated per request."""
    cfg, model, params = gpt

    def probe():
        g = InterventionGraph()
        t = g.add("tap_get", site="logits", step=0)
        g.mark_saved("lg0", g.add("save", Ref(t.id)))
        return g

    def run(fragmented):
        loop = DecodeLoop(model, params, 4, 32, paged=True, page_size=8)
        if fragmented:
            x = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 1,
                           request_id="x", pad_to=8)
            y = loop.admit(InterventionGraph(), _batch(cfg, 2, 7, 1), 5,
                           request_id="y", pad_to=8)
            z = loop.admit(InterventionGraph(), _batch(cfg, 1, 5, 2), 1,
                           request_id="z", pad_to=8)
            loop.step()
            assert sorted(loop._free) == [0, 3]
        w = loop.admit(probe(), _batch(cfg, 2, 6, 3), 3, request_id="w",
                       pad_to=8)
        if fragmented:
            assert w.row_list is not None
        loop.run_to_completion()
        return w.result()

    frag, solo = run(True), run(False)
    np.testing.assert_array_equal(np.asarray(frag.tokens),
                                  np.asarray(solo.tokens))
    np.testing.assert_array_equal(np.asarray(frag.saves["lg0"]),
                                  np.asarray(solo.saves["lg0"]))


def test_admission_failure_leaks_nothing(gpt):
    """An admission the page pool cannot serve raises the structured
    deficit and leaves rows, pages, and reservations untouched."""
    cfg, model, params = gpt
    # 6 usable pages of 8 slots; a 32-token-lifetime request needs 4
    loop = DecodeLoop(model, params, 4, 32, paged=True, page_size=8,
                      num_pages=FIRST_PAGE + 6)
    a = loop.admit(InterventionGraph(), _batch(cfg, 1, 9, 0), 24,
                   request_id="a")
    assert loop.cache is not None
    free_before = loop.free_rows()
    pages_avail = loop.pages_available()
    with pytest.raises(SlotAllocationError) as ei:
        loop.admit(InterventionGraph(), _batch(cfg, 1, 9, 1), 24,
                   request_id="b")
    assert ei.value.pages_requested == 4
    assert ei.value.pages_free == pages_avail
    assert "pages requested" in ei.value.deficit()
    assert loop.free_rows() == free_before
    assert loop.pages_available() == pages_avail
    loop.run_to_completion()
    assert a.result().tokens.shape == (1, 24)


# ------------------------------------------------------- ragged window rings
def test_ragged_window_prefill_admits_and_matches_solo():
    """Ragged prompts into a sliding-window ring used to refuse
    (NotImplementedError); per-row ring alignment now serves them, and the
    group admission matches solo admissions exactly — paged and dense."""
    cfg = R.get_config("paper-gpt-small", reduced=True, sliding_window=8)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))

    def run(paged, group):
        loop = DecodeLoop(model, params, 4, 24, cache_kind="window",
                          paged=paged, page_size=4)
        if group:  # ONE merged ragged prefill (lengths differ inside it)
            srs = loop.admit_group(
                [(InterventionGraph(), _batch(cfg, 1, 11, 0), 4, "long"),
                 (InterventionGraph(), _batch(cfg, 1, 6, 1), 4, "short")],
                pad_to=12)
        else:
            srs = [loop.admit(InterventionGraph(), _batch(cfg, 1, 11, 0), 4,
                              request_id="long", pad_to=12),
                   loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 1), 4,
                              request_id="short", pad_to=12)]
        loop.run_to_completion()
        return {sr.request_id: np.asarray(sr.result().tokens) for sr in srs}

    solo_dense = run(False, group=False)
    for paged in (False, True):
        got = run(paged, group=True)
        for k in solo_dense:
            np.testing.assert_array_equal(got[k], solo_dense[k])


# ------------------------------------------------------------ merge analysis
def test_check_merge_plan_rejects_overlapping_index_plans():
    g = InterventionGraph()
    diags = analysis.check_merge_plan([g, g], [2, 2], starts=[(0, 2), (2, 3)])
    errs = [d for d in diags if d.severity == "error"]
    assert errs and any(d.code == "row-overlap" for d in errs)
    assert any("share rows [2]" in d.message for d in errs)
    # disjoint index plans (and mixed int/index) are clean
    assert not analysis.check_merge_plan([g, g], [2, 2],
                                         starts=[(0, 3), (1, 2)])
    assert not analysis.check_merge_plan([g, g], [2, 2], starts=[0, (2, 3)])


def test_check_merge_plan_rejects_bad_row_sets():
    g = InterventionGraph()
    dup = analysis.check_merge_plan([g], [2], starts=[(1, 1)])
    assert any(d.code == "row-bounds" for d in dup)
    oob = analysis.check_merge_plan([g], [2], starts=[(0, 9)], num_rows=4)
    assert any(d.code == "row-bounds" for d in oob)
    wrong = analysis.check_merge_plan([g], [3], starts=[(0, 1)])
    assert any(d.severity == "error" for d in wrong)


def test_check_page_plan_proves_soundness():
    bt = np.zeros((4, 3), np.int32)
    bt[0] = [2, 3, 0]
    bt[1] = [4, 0, 0]
    clean = analysis.check_page_plan(bt, [[0], [1]], num_pages=6)
    assert not [d for d in clean if d.severity == "error"]
    # out-of-bounds page reference
    bt[1, 1] = 9
    oob = analysis.check_page_plan(bt, [[0], [1]], num_pages=6)
    assert any(d.code == "page-bounds" for d in oob)
    bt[1, 1] = 1  # reserved trash page must never be referenced
    rsv = analysis.check_page_plan(bt, [[0], [1]], num_pages=6)
    assert any(d.code == "page-bounds" for d in rsv)
    bt[1, 1] = 3  # shared with tenant 0 -> overlap
    shared = analysis.check_page_plan(bt, [[0], [1]], num_pages=6)
    assert any(d.code == "page-overlap" for d in shared)


# ------------------------------------------------------------- paged kernel
def test_paged_kernel_matches_dense_kernel_bit_exact():
    """The scalar-prefetch paged pallas kernel equals the dense positional
    kernel run on the gathered view with block_k = page_size — including
    ragged rows, null pages, and sliding windows (interpret mode)."""
    from repro.kernels.flash_attention import (
        PAD_LIMIT,
        flash_attention_kernel_call,
        paged_flash_attention_kernel_call,
    )

    rng = np.random.default_rng(0)
    B, H, K, hd, ps, nb = 3, 4, 2, 8, 4, 5
    T = nb * ps
    k_pool = np.zeros((2 + B * nb, K, ps, hd), np.float32)
    v_pool = np.zeros_like(k_pool)
    bt = np.zeros((B, nb), np.int32)
    k_pos = np.full((B, T), PAD_LIMIT, np.int32)
    kd = np.zeros((B, K, T, hd), np.float32)
    vd = np.zeros_like(kd)
    lens, page = [7, 16, 11], 2
    for b, L in enumerate(lens):
        for blk in range(-(-L // ps)):
            bt[b, blk] = page
            lo, hi = blk * ps, min(L, blk * ps + ps)
            k_pool[page] = rng.standard_normal((K, ps, hd)).astype(np.float32)
            v_pool[page] = rng.standard_normal((K, ps, hd)).astype(np.float32)
            kd[b, :, lo:lo + ps] = k_pool[page]
            vd[b, :, lo:lo + ps] = v_pool[page]
            k_pos[b, lo:hi] = np.arange(lo, hi)
            page += 1
    q = rng.standard_normal((B, H, 1, hd)).astype(np.float32)
    q_pos = np.array([[L] for L in lens], np.int32)

    for window in (None, 6):
        paged = paged_flash_attention_kernel_call(
            jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(bt), jnp.asarray(q_pos), jnp.asarray(k_pos),
            causal=True, window=window, interpret=True)
        dense = flash_attention_kernel_call(
            jnp.asarray(q), jnp.asarray(kd), jnp.asarray(vd),
            jnp.asarray(q_pos), jnp.asarray(k_pos),
            causal=True, window=window, block_k=ps, interpret=True)
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))


def test_paged_ops_wrapper_layouts():
    """kernels.ops.paged_flash_attention round-trips the models' grouped
    query layout and the pools' (page, slot, kv_head, hd) layout."""
    from repro.kernels import ops
    from repro.kernels.flash_attention import PAD_LIMIT

    rng = np.random.default_rng(1)
    B, S, K, G, hd, ps, nb = 2, 1, 2, 2, 8, 4, 3
    P = 2 + B * nb
    qg = rng.standard_normal((B, S, K, G, hd)).astype(np.float32)
    k_pool = rng.standard_normal((P, ps, K, hd)).astype(np.float32)
    v_pool = rng.standard_normal((P, ps, K, hd)).astype(np.float32)
    bt = np.arange(2, 2 + B * nb, dtype=np.int32).reshape(B, nb)
    k_pos = np.full((B, nb * ps), PAD_LIMIT, np.int32)
    lens = [9, 12]
    for b, L in enumerate(lens):
        k_pos[b, :L] = np.arange(L)
    q_pos = np.asarray([[L] for L in lens], np.int32)
    out = ops.paged_flash_attention(
        jnp.asarray(qg), jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(bt), jnp.asarray(q_pos), jnp.asarray(k_pos))
    assert out.shape == (B, S, K, G, hd)
    # reference: dense gather then ops.flash_attention
    kd = np.stack([k_pool[bt[b]].reshape(nb * ps, K, hd) for b in range(B)])
    vd = np.stack([v_pool[bt[b]].reshape(nb * ps, K, hd) for b in range(B)])
    ref = ops.flash_attention(
        jnp.asarray(qg), jnp.asarray(kd), jnp.asarray(vd),
        q_pos=jnp.asarray(q_pos), k_pos=jnp.asarray(k_pos))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-6, atol=2e-6)


# ------------------------------------------------------- engine & scheduler
def test_zero_recompiles_paged_varied_schedule(gpt):
    """A 10-admission varied-length schedule with mid-decode page growth
    performs ZERO new compiles on its second run: block-table updates are
    value-only, placements reuse traced scatter signatures."""
    cfg, model, params = gpt
    engine = InferenceEngine(model, params, mode="unrolled")

    def run_schedule():
        loop = engine.start_decode_loop(4, 32, page_size=4)
        assert loop.paged
        lens = [9, 12, 15, 10, 14, 11, 13, 9, 15, 12]
        srs = []
        for i, L in enumerate(lens):
            while loop.free_rows() == 0:
                loop.step()
            srs.append(loop.admit(InterventionGraph(), _batch(cfg, 1, L, i),
                                  2 + i % 5, request_id=i, pad_to=15))
            loop.step()
        loop.run_to_completion()
        return srs

    run_schedule()  # warmup traces
    c0 = engine.stats.compiles
    srs = run_schedule()
    assert engine.stats.compiles == c0, "steady-state must not retrace"
    assert engine.stats.page_allocs > 0 and engine.stats.page_frees > 0
    solo = InferenceEngine(model, params, mode="unrolled")
    res = solo.generate_interleaved(InterventionGraph(),
                                    _batch(cfg, 1, 15, 2), 4)
    np.testing.assert_array_equal(np.asarray(srs[2].result().tokens),
                                  np.asarray(res.tokens))


def test_engine_stats_gain_paged_counters(gpt):
    cfg, model, params = gpt
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(4, 32, page_size=8)
    loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 3, request_id="a")
    loop.run_to_completion()
    snap = engine.stats.snapshot()
    for key in ("page_allocs", "page_frees", "pages_in_use", "pages_free",
                "page_occupancy", "alloc_retries", "frag_events_avoided"):
        assert key in snap
    assert snap["page_allocs"] > 0 and snap["page_frees"] > 0
    assert snap["pages_in_use"] == 0
    assert snap["pages_free"] == loop.usable_pages()


def test_scheduler_caps_admission_retries_with_deficit(gpt):
    """A ticket that keeps bouncing on page exhaustion terminates with the
    allocator's structured deficit instead of requeue-spinning."""
    cfg, model, params = gpt
    engine = InferenceEngine(model, params, mode="unrolled")
    # cap=1: the whole inter-retirement stretch fuses into one window, so
    # ONE admission boundary passes before the hog frees its pages — the
    # first bounce must already be terminal to observe the cap
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=7,
                              num_slots=2, slot_max_len=32,
                              alloc_retry_cap=1)
    # pool of 6 usable pages; the resident's lifetime need is 4
    sched._loop = engine.start_decode_loop(2, 32, page_size=4,
                                           num_pages=FIRST_PAGE + 6)
    # widths 7 and 9 fall in DIFFERENT length buckets (slack 7), so the
    # two requests plan separately: the hog admits (4 pages) and the
    # second bounces on the 2 remaining pages every boundary
    hog = sched.submit(Request(graph=InterventionGraph(),
                               batch=_batch(cfg, 1, 7, 0),
                               max_new_tokens=10))
    starving = sched.submit(Request(graph=InterventionGraph(),
                                    batch=_batch(cfg, 1, 9, 1),
                                    max_new_tokens=6))
    done = sched.drain()
    assert len(done) == 2
    assert hog.error is None
    assert starving.error is not None
    assert "allocation retries" in starving.error
    assert "pages requested" in starving.error
    assert starving.alloc_retries == 1
    assert engine.stats.alloc_retries >= 1


def test_paged_pool_admits_beyond_dense_budget(gpt):
    """The capacity claim at loop level: with a pool HALF the dense
    footprint, short mixed-length requests still all admit concurrently —
    the dense layout would need a full max_len row each."""
    cfg, model, params = gpt
    # dense 4 rows x 32 slots = 128 cells; paged pool: 8 rows, 64 cells
    loop = DecodeLoop(model, params, 8, 32, paged=True, page_size=4,
                      num_pages=FIRST_PAGE + 16)
    srs = [loop.admit(InterventionGraph(), _batch(cfg, 1, 5, i), 3,
                      request_id=i, pad_to=8) for i in range(6)]
    assert len(loop.resident) == 6  # 6 concurrent rows on 64 cells
    loop.run_to_completion()
    for i, sr in enumerate(srs):
        ref = DecodeLoop(model, params, 8, 32)
        want = ref.admit(InterventionGraph(), _batch(cfg, 1, 5, i), 3,
                         request_id=i, pad_to=8)
        ref.run_to_completion()
        np.testing.assert_array_equal(np.asarray(sr.result().tokens),
                                      np.asarray(want.result().tokens))


def test_build_paged_cache_families(family):
    """Pool construction: every KV family pages its time-axis leaves and
    keeps fixed extras dense; ssm has nothing to page."""
    arch, cfg, model, params = family
    pc = build_paged_cache(model, 2, 16, "full", page_size=4,
                           num_pages=FIRST_PAGE + 8)
    if FAMILIES[arch] == "ssm":
        assert pc is None
        return
    assert isinstance(pc, PagedKVCache)
    assert pc.block_tables.shape == (2, 4)
    for k in pc.paged_keys:
        assert pc.pool[k].shape[1:3] == (FIRST_PAGE + 8, 4)
    from repro.models.paged import dense_view

    dv = dense_view(pc)
    ref = model.init_cache(2, 16, kind="full")
    assert sorted(dv.data) == sorted(ref.data)
    for k in dv.data:
        assert dv.data[k].shape == ref.data[k].shape, k
