"""Interleaving engine: getters, setters, write-back, grads, scan mode, jit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import GraphValidationError
from repro.core.interleave import run_interleaved
from repro.core.serialize import dumps, loads

I = np.eye(4, dtype=np.float32)


def expected(x, stages):
    h = np.asarray(x)
    for s in stages:
        h = s(h)
    return h


class TestUnrolled:
    def test_reads(self, tiny, x2x4):
        with tiny.trace(x2x4):
            h1 = tiny.layers[1].output.save()
            out = tiny.output.save()
        np.testing.assert_allclose(h1.value, np.asarray(x2x4) @ I @ (2 * I))
        np.testing.assert_allclose(out.value, np.asarray(x2x4) @ I @ (2 * I) @ (3 * I))

    def test_full_site_replacement(self, tiny, x2x4):
        with tiny.trace(x2x4):
            tiny.layers[1].output = tiny.layers[1].output * 0.0
            out = tiny.output.save()
        np.testing.assert_allclose(out.value, np.zeros((2, 4)))

    def test_indexed_writeback(self, tiny, x2x4):
        with tiny.trace(x2x4):
            tiny.layers[0].output[0, :] = 7.0
            out = tiny.output.save()
        h = np.asarray(x2x4) @ I
        h[0, :] = 7.0
        np.testing.assert_allclose(out.value, h @ (2 * I) @ (3 * I))

    def test_activation_patching(self, tiny, x2x4):
        with tiny.trace(x2x4):
            tiny.layers[1].output[1, :] = tiny.layers[1].output[0, :]
            out = tiny.output.save()
        h = np.asarray(x2x4) @ I @ (2 * I)
        h[1] = h[0]
        np.testing.assert_allclose(out.value, h @ (3 * I))

    def test_sequential_writebacks_compose(self, tiny, x2x4):
        with tiny.trace(x2x4):
            tiny.layers[0].output[0, 0] = 5.0
            tiny.layers[0].output[0, 1] = 6.0
            out = tiny.output.save()
        h = np.asarray(x2x4) @ I
        h[0, 0], h[0, 1] = 5.0, 6.0
        np.testing.assert_allclose(out.value, h @ (2 * I) @ (3 * I))

    def test_read_after_write_sees_write(self, tiny, x2x4):
        with tiny.trace(x2x4):
            tiny.layers[0].output[0, :] = 1.0
            snap = tiny.layers[0].output.save()
        assert np.allclose(np.asarray(snap.value)[0], 1.0)

    def test_cross_layer_dataflow(self, tiny, x2x4):
        # getter at layer 0 feeds setter at layer 2 (forward in time: OK)
        with tiny.trace(x2x4):
            early = tiny.layers[0].output
            tiny.layers[2].output = early * 1.0
            out = tiny.output.save()
        np.testing.assert_allclose(out.value, np.asarray(x2x4) @ I)

    def test_derived_ops_and_logs(self, tiny, x2x4):
        with tiny.trace(x2x4) as tr:
            v = (tiny.layers[2].output * 2.0 + 1.0).mean().save("m")
            tr.log(v)
        h = np.asarray(x2x4) @ I @ (2 * I) @ (3 * I)
        np.testing.assert_allclose(v.value, (h * 2 + 1).mean(), rtol=1e-6)
        assert len(tr.logs) == 1

    def test_grad(self, tiny, x2x4):
        with tiny.trace(x2x4) as tr:
            g = tiny.layers[1].output.grad.save("g")
            loss = tiny.output.save("o").sum().save("loss")
            tr.backward(loss)
        np.testing.assert_allclose(tr.result("g"), np.full((2, 4), 3.0))

    def test_grad_of_patched_forward(self, tiny, x2x4):
        # patch layer 0, grads flow through the patched program
        with tiny.trace(x2x4) as tr:
            tiny.layers[0].output[0, :] = 0.0
            g = tiny.layers[1].output.grad.save("g")
            loss = (tiny.output * tiny.output).sum().save("loss")
            tr.backward(loss)
        h0 = np.asarray(x2x4) @ I
        h0[0, :] = 0.0
        h1 = h0 @ (2 * I)
        out = h1 @ (3 * I)
        expect = (2 * out) @ (3 * I).T
        np.testing.assert_allclose(tr.result("g"), expect, rtol=1e-5)


class TestScanMode:
    def test_reads_match_unrolled(self, tiny, tiny_scan, x2x4):
        with tiny.trace(x2x4):
            a = tiny.layers[1].output.save()
        with tiny_scan.trace(x2x4):
            b = tiny_scan.layers[1].output.save()
        np.testing.assert_allclose(a.value, b.value)

    def test_site_local_setter(self, tiny_scan, x2x4):
        with tiny_scan.trace(x2x4):
            tiny_scan.layers[1].output[0, :] = 0.0
            out = tiny_scan.output.save()
        h = np.asarray(x2x4) @ I @ (2 * I)
        h[0, :] = 0.0
        np.testing.assert_allclose(out.value, h @ (3 * I))

    def test_same_layer_patch(self, tiny_scan, x2x4):
        with tiny_scan.trace(x2x4):
            tiny_scan.layers[1].output[1, :] = tiny_scan.layers[1].output[0, :]
            out = tiny_scan.output.save()
        h = np.asarray(x2x4) @ I @ (2 * I)
        h[1] = h[0]
        np.testing.assert_allclose(out.value, h @ (3 * I))

    def test_cross_layer_forward_flow_carries(self, tiny, tiny_scan, x2x4):
        # forward cross-layer flow threads through the scan carry: getter
        # at layer 0 feeds a setter at layer 2, matching unrolled mode
        with tiny_scan.trace(x2x4):
            early = tiny_scan.layers[0].output
            tiny_scan.layers[2].output = early * 1.0
            out_s = tiny_scan.output.save()
        with tiny.trace(x2x4):
            early = tiny.layers[0].output
            tiny.layers[2].output = early * 1.0
            out_u = tiny.output.save()
        np.testing.assert_allclose(out_s.value, out_u.value)
        np.testing.assert_allclose(out_s.value, np.asarray(x2x4) @ I)

    def test_cross_layer_derived_forward_flow(self, tiny_scan, x2x4):
        # a derived value (not the raw getter) crossing layers also carries
        with tiny_scan.trace(x2x4):
            early = tiny_scan.layers[0].output * 0.5
            tiny_scan.layers[2].output = tiny_scan.layers[2].output + early
            out = tiny_scan.output.save()
        h0 = np.asarray(x2x4) @ I
        h2 = h0 @ (2 * I) @ (3 * I)
        np.testing.assert_allclose(out.value, h2 + 0.5 * h0)

    def test_cross_layer_backward_flow_rejected(self, tiny_scan, x2x4):
        # backward flow (setter consumes a later layer's getter) stays
        # impossible: the value does not exist yet at the setter's site
        with pytest.raises(GraphValidationError):
            with tiny_scan.trace(x2x4):
                late = tiny_scan.layers[2].output
                tiny_scan.layers[0].output = late * 1.0
                tiny_scan.output.save()

    def test_all_layer_reads(self, tiny_scan, x2x4):
        with tiny_scan.trace(x2x4):
            vals = [tiny_scan.layers[i].output.save() for i in range(3)]
        h = np.asarray(x2x4)
        for i, v in enumerate(vals):
            h = h @ (I * (i + 1))
            np.testing.assert_allclose(v.value, h)

    def test_scan_grad(self, tiny_scan, x2x4):
        with tiny_scan.trace(x2x4) as tr:
            g = tiny_scan.layers[1].output.grad.save("g")
            loss = tiny_scan.output.save("o").sum().save("loss")
            tr.backward(loss)
        np.testing.assert_allclose(tr.result("g"), np.full((2, 4), 3.0),
                                   rtol=1e-5)


class TestExecution:
    def test_jit_wrappable(self, tiny, x2x4):
        with tiny.trace(x2x4) as tr:
            tr._deferred = True
            tiny.layers[1].output[0, 0] = 9.0
            tiny.output.save("out")

        @jax.jit
        def run(params, x):
            _, saves, _ = run_interleaved(
                tiny.wrapped_fn, tr.graph, tiny.schedule, (params, x), {}
            )
            return saves["out"]

        r = run(tiny.params, x2x4)
        h = np.asarray(x2x4) @ I @ (2 * I)
        h[0, 0] = 9.0
        np.testing.assert_allclose(r, h @ (3 * I))

    def test_graph_survives_serialization(self, tiny, x2x4):
        with tiny.trace(x2x4) as tr:
            tr._deferred = True
            tiny.layers[0].output[1, :] = -1.0
            tiny.output.save("out")
        g = loads(dumps(tr.graph))
        _, saves, _ = run_interleaved(
            tiny.wrapped_fn, g, tiny.schedule, (tiny.params, x2x4), {}
        )
        h = np.asarray(x2x4) @ I
        h[1, :] = -1.0
        np.testing.assert_allclose(saves["out"], h @ (2 * I) @ (3 * I))

    def test_never_fired_site_raises(self, tiny, x2x4):
        with pytest.raises(GraphValidationError):
            with tiny.trace(x2x4):
                tiny.layers[2].output.save()
                # model only has 3 layers (0..2) — ask for one that exists
                # but the schedule lookup for layer 7 must fail at validate
                tiny.layers[7].output.save()

    def test_empty_graph_is_identity(self, tiny, x2x4):
        out, saves, logs = run_interleaved(
            tiny.wrapped_fn, _empty(), tiny.schedule, (tiny.params, x2x4), {},
        )
        np.testing.assert_allclose(out, np.asarray(x2x4) @ I @ (2 * I) @ (3 * I))
        assert saves == {} and logs == []


def _empty():
    from repro.core.graph import InterventionGraph

    return InterventionGraph()
