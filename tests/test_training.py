"""Training substrate: optimizer, loop, checkpointing, data pipeline,
interleaved training (paper Code Example 5/8 territory)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ByteTokenizer, DataConfig, synthetic_lm_data
from repro.models import registry as R
from repro.training.checkpoint import (
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.training.optimizer import (
    AdamWConfig,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.training.train_loop import make_train_step, train_loop


def test_adamw_minimizes_quadratic():
    init, update = adamw(AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                                     weight_decay=0.0, grad_clip=100.0))
    params = {"w": jnp.array([5.0, -3.0])}
    state = init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(jnp.asarray(0))) < 0.2
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 0.01
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=0.05)


def test_grad_clip():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_loss_decreases_on_synthetic_data():
    cfg = R.get_config("qwen3-8b", reduced=True)
    model = R.build_model("qwen3-8b", cfg)
    params = model.init(jax.random.key(0))
    data = synthetic_lm_data(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)
    )
    _, hist = train_loop(
        model, params, data, steps=25,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=25),
        log_every=24,
    )
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_checkpoint_roundtrip_and_latest():
    tree = {"a": np.arange(6, np.float32).reshape(2, 3)
            if False else np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray(2.5, np.float64)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=3)
        save_checkpoint(d, tree, step=7)
        assert latest_step(d) == 7
        restored, manifest = load_checkpoint(d)
        assert manifest["step"] == 7
        np.testing.assert_array_equal(restored["a"], tree["a"])
        np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "NNsight + NDIF: ünïcode too"
    assert tok.decode(tok.encode(s)) == s
    batch = tok.encode_batch(["ab", "cdef"], pad_to=8)
    assert batch.shape == (2, 8)


def test_interleaved_train_step():
    """An intervention graph interleaved into the training forward: ablate
    an attention output while training; the ablated site's save comes back
    with the metrics."""
    from repro.core.graph import InterventionGraph, Ref

    cfg = R.get_config("qwen3-8b", reduced=True)
    model = R.build_model("qwen3-8b", cfg)
    params = model.init(jax.random.key(0))

    g = InterventionGraph()
    t = g.add("tap_get", site="layers.attn.output", layer=1)
    z = g.add("jnp.zeros_like", Ref(t.id))
    g.add("tap_set", Ref(z.id), site="layers.attn.output", layer=1)
    s = g.add("save", Ref(t.id))
    g.mark_saved("attn1", s)

    init_state, step = make_train_step(
        model, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=5),
        mode="unrolled", graph=g,
    )
    state = init_state(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32),
    }
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert metrics["saves"]["attn1"].shape == (2, 16, cfg.d_model)


def test_synthetic_data_is_learnable_structure():
    cfg = DataConfig(vocab_size=64, seq_len=32, batch_size=4, seed=1)
    it = synthetic_lm_data(cfg)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 32)
    assert b1["tokens"].dtype == np.int32
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
