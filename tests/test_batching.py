"""Parallel co-tenancy: graph merging, slice isolation, result splitting.

Property test: N random per-user interventions executed merged must equal
the same interventions executed separately — user isolation is structural.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._prop import given, settings, st

from repro.core.batching import merge_graphs, split_results
from repro.core.graph import InterventionGraph, Ref
from repro.core.interleave import run_interleaved
from tests.conftest import make_tiny_model

I = np.eye(4, dtype=np.float32)


def user_graph(layer, rows, scale):
    """User intervention: scale their rows at `layer`, save own output."""
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=layer)
    v = g.add("mul", Ref(t.id), scale)
    g.add("tap_set", Ref(v.id), site="layers.output", layer=layer)
    o = g.add("tap_get", site="logits")
    s = g.add("save", Ref(o.id))
    g.mark_saved("out", s)
    return g


def run(model, graph, x):
    _, saves, _ = run_interleaved(
        model.wrapped_fn, graph, model.schedule, (model.params, x), {}
    )
    return saves


def test_merge_two_users_isolated():
    model = make_tiny_model()
    xs = [np.ones((1, 4), np.float32), 2 * np.ones((2, 4), np.float32)]
    graphs = [user_graph(0, 1, 10.0), user_graph(1, 2, -1.0)]
    merged = merge_graphs(graphs, [1, 2])
    batch = np.concatenate(xs)
    saves = run(model, merged.graph, jnp.asarray(batch))
    per_user = split_results(saves, merged)

    for g, x, res in zip(graphs, xs, per_user):
        solo = run(model, g, jnp.asarray(x))
        np.testing.assert_allclose(res["out"], solo["out"], rtol=1e-6)


def test_grad_graphs_refuse_merge():
    g = InterventionGraph()
    g.add("grad_get", site="logits")
    with pytest.raises(ValueError, match="grad"):
        merge_graphs([g], [1])


def test_save_name_collision_safe():
    graphs = [user_graph(0, 1, 2.0), user_graph(0, 1, 3.0)]
    merged = merge_graphs(graphs, [1, 1])
    names = set(merged.graph.saves)
    assert names == {"r0/out", "r1/out"}


def test_cross_request_same_site_isolated():
    """Request A writes a site, request B reads the SAME site: B must see
    its own rows untouched by A's write (and vice versa)."""
    model = make_tiny_model()
    ga = InterventionGraph()
    t = ga.add("tap_get", site="layers.output", layer=1)
    v = ga.add("mul", Ref(t.id), np.float32(100.0))
    ga.add("tap_set", Ref(v.id), site="layers.output", layer=1)
    o = ga.add("tap_get", site="logits")
    ga.mark_saved("out", ga.add("save", Ref(o.id)))

    gb = InterventionGraph()
    tb = gb.add("tap_get", site="layers.output", layer=1)
    gb.mark_saved("acts", gb.add("save", Ref(tb.id)))
    ob = gb.add("tap_get", site="logits")
    gb.mark_saved("out", gb.add("save", Ref(ob.id)))

    xa = np.ones((1, 4), np.float32)
    xb = 3 * np.ones((2, 4), np.float32)
    merged = merge_graphs([ga, gb], [1, 2])
    saves = run(model, merged.graph, jnp.asarray(np.concatenate([xa, xb])))
    res_a, res_b = split_results(saves, merged)

    solo_a = run(model, ga, jnp.asarray(xa))
    solo_b = run(model, gb, jnp.asarray(xb))
    # B's read of the shared site sees ONLY its own (unscaled) rows
    np.testing.assert_allclose(res_b["acts"], solo_b["acts"], rtol=1e-6)
    assert np.abs(np.asarray(res_b["acts"])).max() < 50  # A's 100x absent
    # and downstream outputs match solo runs on both sides
    np.testing.assert_allclose(res_a["out"], solo_a["out"], rtol=1e-6)
    np.testing.assert_allclose(res_b["out"], solo_b["out"], rtol=1e-6)


def test_cross_request_reader_before_writer_isolated():
    """Same as above with the reader submitted FIRST (order must not
    matter: the reader's slice comes from the pristine shared getter)."""
    model = make_tiny_model()
    gb = InterventionGraph()
    tb = gb.add("tap_get", site="layers.output", layer=0)
    gb.mark_saved("acts", gb.add("save", Ref(tb.id)))

    ga = InterventionGraph()
    t = ga.add("tap_get", site="layers.output", layer=0)
    v = ga.add("add", Ref(t.id), np.float32(99.0))
    ga.add("tap_set", Ref(v.id), site="layers.output", layer=0)
    o = ga.add("tap_get", site="logits")
    ga.mark_saved("out", ga.add("save", Ref(o.id)))

    xb = np.ones((1, 4), np.float32)
    xa = np.ones((1, 4), np.float32)
    merged = merge_graphs([gb, ga], [1, 1])
    saves = run(model, merged.graph, jnp.asarray(np.concatenate([xb, xa])))
    res_b, res_a = split_results(saves, merged)
    np.testing.assert_allclose(
        res_b["acts"], run(model, gb, jnp.asarray(xb))["acts"], rtol=1e-6)
    np.testing.assert_allclose(
        res_a["out"], run(model, ga, jnp.asarray(xa))["out"], rtol=1e-6)


def test_split_results_save_name_containing_slash():
    """User save names may contain '/' — only the FIRST separator is the
    request prefix."""
    g = InterventionGraph()
    t = g.add("tap_get", site="logits")
    g.mark_saved("probe/layer0/acts", g.add("save", Ref(t.id)))
    merged = merge_graphs([g, g], [1, 1])
    assert set(merged.graph.saves) == {
        "r0/probe/layer0/acts", "r1/probe/layer0/acts"
    }
    out = split_results(
        {"r0/probe/layer0/acts": 1, "r1/probe/layer0/acts": 2}, merged
    )
    assert out[0] == {"probe/layer0/acts": 1}
    assert out[1] == {"probe/layer0/acts": 2}


@given(
    st.lists(
        st.tuples(
            st.integers(0, 2),            # layer
            st.integers(1, 3),            # rows
            st.floats(-3, 3, width=32),   # scale
        ),
        min_size=1,
        max_size=4,
    )
)
@settings(max_examples=20, deadline=None)
def test_property_merged_equals_solo(users):
    model = make_tiny_model()
    rng = np.random.default_rng(0)
    graphs, xs, sizes = [], [], []
    for layer, rows, scale in users:
        graphs.append(user_graph(layer, rows, np.float32(scale)))
        xs.append(rng.standard_normal((rows, 4)).astype(np.float32))
        sizes.append(rows)
    merged = merge_graphs(graphs, sizes)
    saves = run(model, merged.graph, jnp.asarray(np.concatenate(xs)))
    per_user = split_results(saves, merged)
    for g, x, res in zip(graphs, xs, per_user):
        solo = run(model, g, jnp.asarray(x))
        np.testing.assert_allclose(res["out"], solo["out"], rtol=1e-5,
                                   atol=1e-5)
