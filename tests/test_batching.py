"""Parallel co-tenancy: graph merging, slice isolation, result splitting.

Property test: N random per-user interventions executed merged must equal
the same interventions executed separately — user isolation is structural.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.batching import merge_graphs, split_results
from repro.core.graph import InterventionGraph, Ref
from repro.core.interleave import run_interleaved
from tests.conftest import make_tiny_model

I = np.eye(4, dtype=np.float32)


def user_graph(layer, rows, scale):
    """User intervention: scale their rows at `layer`, save own output."""
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=layer)
    v = g.add("mul", Ref(t.id), scale)
    g.add("tap_set", Ref(v.id), site="layers.output", layer=layer)
    o = g.add("tap_get", site="logits")
    s = g.add("save", Ref(o.id))
    g.mark_saved("out", s)
    return g


def run(model, graph, x):
    _, saves, _ = run_interleaved(
        model.wrapped_fn, graph, model.schedule, (model.params, x), {}
    )
    return saves


def test_merge_two_users_isolated():
    model = make_tiny_model()
    xs = [np.ones((1, 4), np.float32), 2 * np.ones((2, 4), np.float32)]
    graphs = [user_graph(0, 1, 10.0), user_graph(1, 2, -1.0)]
    merged = merge_graphs(graphs, [1, 2])
    batch = np.concatenate(xs)
    saves = run(model, merged.graph, jnp.asarray(batch))
    per_user = split_results(saves, merged)

    for g, x, res in zip(graphs, xs, per_user):
        solo = run(model, g, jnp.asarray(x))
        np.testing.assert_allclose(res["out"], solo["out"], rtol=1e-6)


def test_grad_graphs_refuse_merge():
    g = InterventionGraph()
    g.add("grad_get", site="logits")
    with pytest.raises(ValueError, match="grad"):
        merge_graphs([g], [1])


def test_save_name_collision_safe():
    graphs = [user_graph(0, 1, 2.0), user_graph(0, 1, 3.0)]
    merged = merge_graphs(graphs, [1, 1])
    names = set(merged.graph.saves)
    assert names == {"r0/out", "r1/out"}


@given(
    st.lists(
        st.tuples(
            st.integers(0, 2),            # layer
            st.integers(1, 3),            # rows
            st.floats(-3, 3, width=32),   # scale
        ),
        min_size=1,
        max_size=4,
    )
)
@settings(max_examples=20, deadline=None)
def test_property_merged_equals_solo(users):
    model = make_tiny_model()
    rng = np.random.default_rng(0)
    graphs, xs, sizes = [], [], []
    for layer, rows, scale in users:
        graphs.append(user_graph(layer, rows, np.float32(scale)))
        xs.append(rng.standard_normal((rows, 4)).astype(np.float32))
        sizes.append(rows)
    merged = merge_graphs(graphs, sizes)
    saves = run(model, merged.graph, jnp.asarray(np.concatenate(xs)))
    per_user = split_results(saves, merged)
    for g, x, res in zip(graphs, xs, per_user):
        solo = run(model, g, jnp.asarray(x))
        np.testing.assert_allclose(res["out"], solo["out"], rtol=1e-5,
                                   atol=1e-5)
