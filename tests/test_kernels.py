"""Per-kernel validation: shape/dtype sweeps, interpret-mode vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._prop import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel_call
from repro.kernels.ssd_scan import ssd_scan_kernel_call


def _qkv(B, H, K, S, T, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, K, T, hd), dtype)
    v = jax.random.normal(ks[2], (B, K, T, hd), dtype)
    return q, k, v


SHAPES = [
    # B, H, K, S, T, hd, bq, bk
    (1, 4, 4, 64, 64, 32, 32, 32),   # MHA, even blocks
    (2, 8, 2, 96, 96, 16, 32, 32),   # GQA 4:1
    (1, 4, 1, 50, 50, 32, 32, 32),   # MQA + ragged seq (padding path)
    (2, 2, 2, 33, 65, 64, 16, 32),   # ragged both dims
    (1, 8, 4, 128, 128, 128, 64, 64),  # MXU-aligned head dim
]


@pytest.mark.parametrize("B,H,K,S,T,hd,bq,bk", SHAPES)
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 24)])
def test_flash_attention_sweep(B, H, K, S, T, hd, bq, bk, causal, window):
    q, k, v = _qkv(B, H, K, S, T, hd, jnp.float32)
    out = flash_attention_kernel_call(
        q, k, v, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=True,
    )
    expect = ref.reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, rtol):
    q, k, v = _qkv(1, 4, 2, 64, 64, 32, dtype)
    out = flash_attention_kernel_call(q, k, v, causal=True,
                                      block_q=32, block_k=32, interpret=True)
    expect = ref.reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32),
        rtol=rtol, atol=rtol,
    )
    assert out.dtype == dtype


def test_flash_attention_block_invariance():
    q, k, v = _qkv(1, 2, 2, 128, 128, 32, jnp.float32)
    outs = [
        flash_attention_kernel_call(q, k, v, causal=True, block_q=bq,
                                    block_k=bk, interpret=True)
        for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


@given(
    st.integers(1, 2),            # B
    st.sampled_from([1, 2, 4]),   # K
    st.integers(1, 4),            # G
    st.integers(2, 70),           # S
    st.sampled_from([16, 32]),    # hd
)
@settings(max_examples=15, deadline=None)
def test_flash_attention_property(B, K, G, S, hd):
    q, k, v = _qkv(B, K * G, K, S, S, hd, jnp.float32, seed=S)
    out = flash_attention_kernel_call(q, k, v, causal=True,
                                      block_q=16, block_k=16, interpret=True)
    expect = ref.reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, expect, rtol=5e-5, atol=5e-5)


# ---------------------------------------------------------------------- SSD
def _ssd_inputs(B, S, H, P, N, seed=0):
    ks = jax.random.split(jax.random.key(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, N))
    C = jax.random.normal(ks[4], (B, S, N))
    D = jnp.linspace(0.5, 1.5, H)
    return x, dt, A, B_, C, D


SSD_SHAPES = [
    (1, 32, 2, 8, 16, 8),
    (2, 40, 4, 16, 24, 8),    # ragged: 40 % 8 == 0 but 40 % 16 != 0
    (1, 33, 2, 8, 16, 16),    # ragged with padding
    (2, 64, 8, 32, 32, 32),
]


@pytest.mark.parametrize("B,S,H,P,N,chunk", SSD_SHAPES)
def test_ssd_sweep(B, S, H, P, N, chunk):
    x, dt, A, B_, C, D = _ssd_inputs(B, S, H, P, N, seed=S)
    y, fin = ssd_scan_kernel_call(x, dt, A, B_, C, D, chunk=chunk,
                                  interpret=True)
    ye, fine = ref.reference_ssd(x, dt, A, B_, C, D)
    np.testing.assert_allclose(y, ye, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(fin, fine, rtol=2e-4, atol=2e-4)


def test_ssd_chunk_invariance():
    x, dt, A, B_, C, D = _ssd_inputs(1, 48, 2, 8, 16)
    outs = [ssd_scan_kernel_call(x, dt, A, B_, C, D, chunk=c, interpret=True)
            for c in (4, 8, 16, 48)]
    for y, f in outs[1:]:
        np.testing.assert_allclose(y, outs[0][0], rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(f, outs[0][1], rtol=2e-4, atol=2e-4)


@given(st.integers(1, 2), st.integers(2, 50), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 16]), st.sampled_from([8, 16]))
@settings(max_examples=15, deadline=None)
def test_ssd_property(B, S, H, P, N):
    x, dt, A, B_, C, D = _ssd_inputs(B, S, H, P, N, seed=S + 7)
    y, fin = ssd_scan_kernel_call(x, dt, A, B_, C, D, chunk=8, interpret=True)
    ye, fine = ref.reference_ssd(x, dt, A, B_, C, D)
    np.testing.assert_allclose(y, ye, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(fin, fine, rtol=3e-4, atol=3e-4)


def test_model_chunked_path_matches_oracle():
    """The XLA fallback in models/common must agree with the oracle too."""
    from repro.models.common import _ssd_chunked

    x, dt, A, B_, C, D = _ssd_inputs(2, 40, 4, 16, 24)
    y, fin = _ssd_chunked(x, dt, A, B_, C, D, chunk=8)
    ye, fine = ref.reference_ssd(x, dt, A, B_, C, D)
    np.testing.assert_allclose(y, ye, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(fin, fine, rtol=2e-4, atol=2e-4)


def test_model_attention_impls_agree():
    from repro.models.common import attention

    B, S, K, G, hd = 2, 96, 2, 4, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, K * G, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    outs = {}
    for impl in ("dense", "chunked", "pallas"):
        outs[impl] = attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                               impl=impl)
    np.testing.assert_allclose(outs["dense"], outs["chunked"], rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(outs["dense"], outs["pallas"], rtol=2e-5,
                               atol=2e-5)
