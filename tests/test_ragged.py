"""Padding-aware parallel co-tenancy: ragged-length requests merged into one
forward / one decode loop.

Layers under test:
  * model level — a right-padded row with ``lengths`` masking is BIT-exact
    vs the same row run solo (same batch size, so no GEMM-tiling noise);
  * merger level — position-aware unpadding: saves come back at each
    request's true length, setters confined to real rows AND positions;
  * scheduler level — length-bucketed grouping (``pad_slack``), padding
    stats, ragged generation sharing one decode loop;
  * serving level — ``lengths`` on the wire, the ``stats`` endpoint.

Merged-vs-solo comparisons use the same 1e-5 tolerance as the pre-existing
exact-shape merging tests: executing B rows in one batch instead of two
reorders GEMM reductions at the ~1e-6 level even WITHOUT padding (verified
by test_same_shape_merge_noise_baseline); padding adds nothing on top.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import merge_graphs, split_results
from repro.core.generation import run_generation
from repro.core.graph import GraphValidationError, InterventionGraph, Ref
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request, _merge_key

FAMILIES = {
    "paper-gpt-small": "transformer",
    "mamba2-1.3b": "ssm",
    "zamba2-2.7b": "hybrid",
    "seamless-m4t-large-v2": "encdec",
}


@pytest.fixture(scope="module", params=sorted(FAMILIES))
def family(request):
    arch = request.param
    cfg = R.get_config(arch, reduced=True)
    model = R.build_model(arch, cfg)
    params = model.init(jax.random.key(0))
    return arch, cfg, model, params


def _batch(cfg, rows, seq, seed, src=None):
    rng = np.random.default_rng(seed)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (rows, seq)).astype(np.int32)}
    if cfg.arch_type == "audio":
        T = src or cfg.n_source_frames
        batch["src_embeds"] = rng.standard_normal(
            (rows, T, cfg.d_model)).astype(np.float32)
    return batch


def _probe_site(cfg):
    return "decoder.output" if cfg.arch_type == "audio" else "layers.output"


def _probe_req(cfg, layer, rows, seq, seed, scale=None, site=None):
    """Save activations (+ optionally scale-set them) at `site`, save logits."""
    site = site or _probe_site(cfg)
    g = InterventionGraph()
    t = g.add("tap_get", site=site, layer=layer)
    if scale is not None:
        v = g.add("mul", Ref(t.id), np.float32(scale))
        g.add("tap_set", Ref(v.id), site=site, layer=layer)
    g.mark_saved("acts", g.add("save", Ref(t.id)))
    o = g.add("tap_get", site="logits")
    g.mark_saved("out", g.add("save", Ref(o.id)))
    return Request(graph=g, batch=_batch(cfg, rows, seq, seed))


# ------------------------------------------------------------- model level
def test_padded_row_bit_exact_vs_solo(family):
    """Right padding + lengths masking is inert: real rows' logits are
    BIT-identical to an unpadded forward (encdec: 1e-5, its non-causal
    encoder softmax reorders one reduction over masked keys)."""
    arch, cfg, model, params = family
    rng = np.random.default_rng(0)
    B, S, pad = 2, 10, 5
    batch = _batch(cfg, B, S + pad, 0)
    batch["lengths"] = np.array([S + pad, S], np.int32)
    if cfg.arch_type == "audio":
        batch["src_lengths"] = np.array(
            [cfg.n_source_frames, cfg.n_source_frames - 6], np.int32)
        batch["src_embeds"][1, cfg.n_source_frames - 6:] = 7.7  # poison pad
    batch["tokens"][1, S:] = 3  # poison the padding — it must not matter
    out = model.forward(params, batch, mode="unrolled")

    solo_batch = {"tokens": batch["tokens"][1:2, :S]}
    if cfg.arch_type == "audio":
        solo_batch["src_embeds"] = batch["src_embeds"][1:2, :cfg.n_source_frames - 6]
    solo = model.forward(params, solo_batch, mode="unrolled")
    got = np.asarray(out["logits"])[1, :S]
    want = np.asarray(solo["logits"])[0]
    if FAMILIES[arch] == "encdec":
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_array_equal(got, want)


def test_same_shape_merge_noise_baseline():
    """The pre-existing exact-shape merger is NOT bit-exact vs solo (GEMM
    tiling differs with batch size) — documents why merged-vs-solo
    comparisons below use 1e-5, while padded-vs-solo at fixed batch size
    (above) is held to exact."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=0)
    reqs = [_probe_req(cfg, 0, 1, 8, s) for s in range(2)]
    tickets = [sched.submit(r) for r in reqs]
    sched.drain()
    for r, t in zip(reqs, tickets):
        solo, _ = InferenceEngine(model, params).execute(r.graph, r.batch)
        np.testing.assert_allclose(
            np.asarray(t.result["out"]), np.asarray(solo["out"]),
            rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ merged-save parity
def test_ragged_merge_saves_match_solo(family):
    """A group of different-length requests runs as ONE forward; every
    unpadded save matches that request's solo run."""
    arch, cfg, model, params = family
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=16)
    lens = [6, 11, 9]
    reqs = [_probe_req(cfg, s % cfg.n_layers, 1 + s % 2, L, seed=s)
            for s, L in enumerate(lens)]
    tickets = [sched.submit(r) for r in reqs]
    sched.drain()
    assert engine.stats.executions == 1, "ragged group must merge"
    assert engine.stats.merged_groups == 1
    assert engine.stats.padded_tokens > 0
    for r, t in zip(reqs, tickets):
        assert t.error is None, t.error
        solo, _ = InferenceEngine(model, params).execute(r.graph, r.batch)
        S = r.batch["tokens"].shape[1]
        assert t.result["acts"].shape[1] == S, "save must be unpadded"
        for k in ("acts", "out"):
            np.testing.assert_allclose(
                np.asarray(t.result[k]), np.asarray(solo[k]),
                rtol=1e-5, atol=1e-5)


def test_ragged_setter_confined_to_real_positions():
    """A SHORT request's setter must not touch other requests' rows nor its
    own padded positions; a LONG reader sees its rows pristine."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=16)
    writer = _probe_req(cfg, 1, 1, 5, seed=0, scale=100.0)
    reader = _probe_req(cfg, 1, 2, 12, seed=1)
    t_w = sched.submit(writer)
    t_r = sched.submit(reader)
    sched.drain()
    assert engine.stats.executions == 1
    solo_w, _ = InferenceEngine(model, params).execute(writer.graph, writer.batch)
    solo_r, _ = InferenceEngine(model, params).execute(reader.graph, reader.batch)
    # reader's rows (merged at FULL length alongside a padded writer) pristine
    np.testing.assert_allclose(np.asarray(t_r.result["acts"]),
                               np.asarray(solo_r["acts"]), rtol=1e-5, atol=1e-5)
    # writer's own downstream logits match its solo intervened run
    np.testing.assert_allclose(np.asarray(t_w.result["out"]),
                               np.asarray(solo_w["out"]), rtol=1e-5, atol=1e-5)


def test_ragged_user_ops_see_solo_shapes():
    """Positional indexing inside a user graph (x[:, -1]) must grab the
    request's REAL last token, not padding."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))

    def last_tok_req(seq, seed):
        g = InterventionGraph()
        t = g.add("tap_get", site="logits")
        last = g.add("getitem", Ref(t.id), (slice(None), -1))
        g.mark_saved("last", g.add("save", Ref(last.id)))
        return Request(graph=g, batch=_batch(cfg, 1, seq, seed))

    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=16)
    reqs = [last_tok_req(5, 0), last_tok_req(9, 1)]
    tickets = [sched.submit(r) for r in reqs]
    sched.drain()
    assert engine.stats.executions == 1
    for r, t in zip(reqs, tickets):
        solo, _ = InferenceEngine(model, params).execute(r.graph, r.batch)
        np.testing.assert_allclose(np.asarray(t.result["last"]),
                                   np.asarray(solo["last"]),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ bucket policy
def test_pad_slack_zero_degenerates_to_exact_match():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=0)
    for s, L in enumerate([6, 7, 6]):
        sched.submit(_probe_req(cfg, 0, 1, L, seed=s))
    done = sched.drain()
    assert engine.stats.executions == 2  # {6, 6} merge, 7 runs alone
    assert all(t.error is None for t in done)


def test_pad_slack_bounds_bucket_width():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    slack = 4
    k0 = _merge_key(_probe_req(cfg, 0, 1, 10, 0), slack)
    assert k0 == _merge_key(_probe_req(cfg, 0, 1, 14, 1), slack)  # same bucket
    assert k0 != _merge_key(_probe_req(cfg, 0, 1, 15, 2), slack)  # next bucket
    # slack=0 keeps the legacy exact-shape key
    assert (_merge_key(_probe_req(cfg, 0, 1, 10, 0), 0)
            != _merge_key(_probe_req(cfg, 0, 1, 11, 0), 0))


def test_grad_requests_still_run_solo():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    g = InterventionGraph()
    g.add("grad_get", site="logits")
    req = Request(graph=g, batch=_batch(cfg, 1, 6, 0))
    assert _merge_key(req, 16) is None


# ------------------------------------------------------- ragged generation
def test_ragged_generation_matches_solo(family):
    """Different prompt lengths share ONE prefill + decode loop; each row's
    generated tokens equal its solo run (greedy ids are exact)."""
    arch, cfg, model, params = family
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=16)
    lens = [7, 4, 6]
    reqs = [Request(graph=InterventionGraph(), batch=_batch(cfg, 1, L, seed=s),
                    max_new_tokens=3)
            for s, L in enumerate(lens)]
    tickets = [sched.submit(r) for r in reqs]
    sched.drain()
    assert engine.stats.generations == 1, "ragged gen requests must merge"
    for r, t in zip(reqs, tickets):
        assert t.error is None, t.error
        solo = InferenceEngine(model, params, mode="unrolled")
        res = solo.generate_interleaved(InterventionGraph(), dict(r.batch), 3)
        np.testing.assert_array_equal(t.result["tokens"], np.asarray(res.tokens))


def test_ragged_generation_with_step_graph_saves():
    """Per-step saves ride the ragged decode loop; prefill saves unpad."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))

    def gen_req(seq, seed):
        g = InterventionGraph()
        t = g.add("tap_get", site="logits", step=0)
        g.mark_saved("lg0", g.add("save", Ref(t.id)))
        from repro.core.graph import PREFILL_STEP
        p = g.add("tap_get", site="embed", step=PREFILL_STEP)
        g.mark_saved("emb", g.add("save", Ref(p.id)))
        return Request(graph=g, batch=_batch(cfg, 1, seq, seed),
                       max_new_tokens=2)

    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=16)
    reqs = [gen_req(5, 0), gen_req(8, 1)]
    tickets = [sched.submit(r) for r in reqs]
    sched.drain()
    assert engine.stats.generations == 1
    for r, t in zip(reqs, tickets):
        assert t.error is None, t.error
        S = r.batch["tokens"].shape[1]
        assert t.result["emb"].shape[1] == S - 1, "prefill save unpads to S-1"
        assert t.result["lg0"].shape == (1, 1, cfg.vocab_size)
        solo = InferenceEngine(model, params, mode="unrolled")
        res = solo.generate_interleaved(r.graph, dict(r.batch), 2)
        np.testing.assert_allclose(np.asarray(t.result["emb"]),
                                   np.asarray(res.saves["emb"]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(t.result["tokens"],
                                      np.asarray(res.tokens))


def test_explicit_per_row_lengths_in_one_request():
    """A client may submit ONE right-padded batch with per-row lengths —
    each row decodes from its own last real token."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (2, 8)).astype(np.int32)
    lengths = np.array([8, 5], np.int32)
    padded = toks.copy()
    padded[1, 5:] = 0
    res = engine.generate_interleaved(
        InterventionGraph(),
        {"tokens": padded, "lengths": lengths}, 4)
    for r, L in enumerate(lengths):
        solo = InferenceEngine(model, params).generate_interleaved(
            InterventionGraph(), {"tokens": toks[r:r + 1, :L]}, 4)
        np.testing.assert_array_equal(np.asarray(res.tokens)[r],
                                      np.asarray(solo.tokens)[0])


# ------------------------------------------------------------------ S == 1
def test_single_token_prompt_generation_tracing(family):
    """lm.generate now accepts S == 1 (direct cache init, the whole prompt
    decoded as step 0) for every family."""
    arch, cfg, model, params = family
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 1)).astype(np.int32))
    extras = {}
    if cfg.arch_type == "audio":
        extras["src_embeds"] = jnp.asarray(rng.standard_normal(
            (2, cfg.n_source_frames, cfg.d_model)).astype(np.float32))
    lm = traced_lm(model, params)
    with lm.generate(toks, max_new_tokens=3, **extras) as tr:
        for _ in tr.steps():
            lm.logits.save("lg")
    assert tr.output_tokens.shape == (2, 3)
    assert np.asarray(tr.result("lg")).shape == (2, 3, cfg.vocab_size)
    # step-0 token == argmax of the single-token forward
    full = model.forward(params, {"tokens": toks, **extras},
                         mode="unrolled")["logits"]
    np.testing.assert_array_equal(
        tr.output_tokens[:, 0], np.argmax(np.asarray(full)[:, -1], -1))


def test_single_token_prompt_rejects_prefill_taps():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    lm = traced_lm(model, params)
    toks = jnp.ones((1, 1), jnp.int32)
    with pytest.raises(GraphValidationError, match="prefill"):
        with lm.generate(toks, max_new_tokens=2) as tr:
            with tr.prefill():
                lm.embed.save("emb")


# ------------------------------------------- scan-mode prefill (hybrid/encdec)
def test_scan_mode_prefill_taps_forced_unrolled():
    """Hybrid/encdec prefill runs a Python layer loop; a generation trace in
    scan mode tapping prefill must still schedule correctly (the prefill
    slice is forced onto the unrolled schedule)."""
    for arch in ("zamba2-2.7b", "seamless-m4t-large-v2"):
        cfg = R.get_config(arch, reduced=True)
        model = R.build_model(arch, cfg)
        assert model.scan_prefill is False
        params = model.init(jax.random.key(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32))
        extras = {}
        if cfg.arch_type == "audio":
            extras["src_embeds"] = jnp.asarray(rng.standard_normal(
                (1, cfg.n_source_frames, cfg.d_model)).astype(np.float32))
        results = {}
        for mode in ("unrolled", "scan"):
            lm = traced_lm(model, params, mode=mode)
            with lm.generate(toks, max_new_tokens=2, **extras) as tr:
                with tr.prefill():
                    if cfg.arch_type == "audio":
                        lm.decoder[1].output.save("pre")
                    else:
                        lm.layers[1].output.save("pre")
                for _ in tr.steps():
                    lm.logits.save("lg")
            results[mode] = tr
        np.testing.assert_array_equal(results["scan"].output_tokens,
                                      results["unrolled"].output_tokens)
        np.testing.assert_allclose(
            np.asarray(results["scan"].result("pre")),
            np.asarray(results["unrolled"].result("pre")),
            rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------- serving wire
def test_server_stats_endpoint_and_ragged_wire():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="parallel", pad_slack=16)
    client = NDIFClient(LoopbackTransport(server.handle), cfg.name)

    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (2, 8)).astype(np.int32)
    lengths = np.array([8, 5], np.int32)
    res = client.generate(toks, max_new_tokens=3, lengths=lengths)
    assert res["tokens"].shape == (2, 3)
    solo = client.generate(toks[1:2, :5], max_new_tokens=3)
    np.testing.assert_array_equal(res["tokens"][1], solo["tokens"][0])

    stats = client.stats()
    assert stats["generations"] == 2
    assert stats["gen_tokens"] == 9
    assert "padding_waste" in stats and "group_sizes" in stats
    assert stats["compiles"] > 0


def test_pallas_impl_supports_ragged_masking():
    """Per-row positions thread into the flash kernel's mask: under
    ``set_attention_impl("pallas")`` a right-padded row is BIT-exact vs the
    same row run solo (padded-vs-solo at fixed batch size, the repo's
    strongest parity bar), and the whole padded batch matches the dense
    impl at the kernel's validation tolerance."""
    from repro.models import common as C

    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (2, 8)).astype(np.int32)
    lengths = np.array([8, 5], np.int32)

    # sentinel positions no longer refuse under pallas
    C.set_attention_impl("pallas")
    try:
        pos = C.valid_positions(jnp.array([3, 5]), 2, 8)
        assert pos.shape == (2, 8)
        assert int(np.asarray(pos)[0, 3]) >= int(C.PAD_LIMIT)
        padded = model.forward(
            params, {"tokens": toks, "lengths": lengths})["logits"]
        solo = model.forward(params, {"tokens": toks[1:2, :5]})["logits"]
    finally:
        C.set_attention_impl("auto")
    np.testing.assert_array_equal(
        np.asarray(padded)[1, :5], np.asarray(solo)[0])
    dense = model.forward(
        params, {"tokens": toks, "lengths": lengths})["logits"]
    np.testing.assert_allclose(
        np.asarray(padded), np.asarray(dense), rtol=2e-5, atol=2e-5)


def test_pallas_ragged_generation_matches_dense():
    """A ragged generation under the pallas impl produces the same greedy
    tokens as the dense impl (prefill masking drives the whole loop)."""
    from repro.models import common as C

    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 9)).astype(np.int32))
    lengths = jnp.asarray([9, 6], jnp.int32)
    want = run_generation(model, params, InterventionGraph(), toks, 3,
                          mode="unrolled", lengths=lengths)
    C.set_attention_impl("pallas")
    try:
        got = run_generation(model, params, InterventionGraph(), toks, 3,
                             mode="unrolled", lengths=lengths)
    finally:
        C.set_attention_impl("auto")
    np.testing.assert_array_equal(np.asarray(got.tokens),
                                  np.asarray(want.tokens))


def test_single_token_generation_request_runs_solo():
    """An S == 1 generation request must not merge into a longer-prompt
    group (it has no prefill execution; merged it would get a zero-length
    prefill slice instead of the solo path's behavior)."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    req1 = Request(graph=InterventionGraph(), batch=_batch(cfg, 1, 1, 0),
                   max_new_tokens=2)
    assert _merge_key(req1, 16) is None
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=16)
    t1 = sched.submit(Request(graph=InterventionGraph(),
                              batch=_batch(cfg, 1, 1, 0), max_new_tokens=2))
    t2 = sched.submit(Request(graph=InterventionGraph(),
                              batch=_batch(cfg, 1, 6, 1), max_new_tokens=2))
    sched.drain()
    assert t1.error is None and t2.error is None
    assert engine.stats.generations == 2  # ran separately
    assert t1.result["tokens"].shape == (1, 2)


def test_ragged_window_cache_prefill_serves():
    """A uniform window crop would evict a short row's still-in-window
    keys; prefill used to refuse (NotImplementedError) rather than decode
    from a corrupt cache.  Per-row ring alignment (PR 7) crops each row by
    ITS OWN length, so the ragged group now admits — and must decode
    exactly like solo admissions of the same rows."""
    from repro.core.generation import DecodeLoop

    cfg = R.get_config("paper-gpt-small", reduced=True, sliding_window=8)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    long_toks = rng.integers(0, cfg.vocab_size, (1, 12)).astype(np.int32)
    short_toks = rng.integers(0, cfg.vocab_size, (1, 5)).astype(np.int32)

    loop = DecodeLoop(model, params, 2, 24, cache_kind="window")
    grp = loop.admit_group(
        [(InterventionGraph(), {"tokens": long_toks}, 3, "long"),
         (InterventionGraph(), {"tokens": short_toks}, 3, "short")],
        pad_to=12)
    loop.run_to_completion()
    got = {sr.request_id: np.asarray(sr.result().tokens) for sr in grp}

    for rid, toks in (("long", long_toks), ("short", short_toks)):
        solo = DecodeLoop(model, params, 2, 24, cache_kind="window")
        want = solo.admit(InterventionGraph(), {"tokens": toks}, 3,
                          request_id=rid, pad_to=12)
        solo.run_to_completion()
        np.testing.assert_array_equal(got[rid],
                                      np.asarray(want.result().tokens))


def test_merge_graphs_lengths_record_roundtrip():
    """Unit-level: merge_graphs with a lengths record emits unpadding
    slices only for the short request and records lengths on the result."""
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.output", layer=0)
    g.mark_saved("acts", g.add("save", Ref(t.id)))
    merged = merge_graphs(
        [g, g], [1, 1],
        lengths=[{"tokens": 4}, {"tokens": 7}],
        site_length_key=lambda s: "tokens",
    )
    assert merged.lengths == [{"tokens": 4}, {"tokens": 7}]
    slices = [n for n in merged.graph.nodes if n.op == "dynamic_slice_in_dim"]
    # r0 (short): row slice + length slice; r1 (max): row slice only
    assert len(slices) == 3
    assert sorted(n.kwargs["axis"] for n in slices) == [0, 0, 1]
    out = split_results({"r0/acts": 1, "r1/acts": 2}, merged)
    assert out == [{"acts": 1}, {"acts": 2}]
