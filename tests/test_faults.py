"""Fault-tolerant serving: deterministic injection + supervised recovery.

Every live test here drives the REAL threaded front door through the wire
protocol while a seeded :class:`FaultPlan` breaks it on purpose — engine
crashes, lost transport messages, allocation bursts, stalls.  The
assertions are the recovery contract: every ticket terminates with a
result or a STRUCTURED error (nothing hangs), survivors stay bit-exact
against the synchronous solo path, and the fault-tolerance counters
(faults_injected / engine_restarts / tickets_requeued / cancellations /
deadline_evictions) account for everything that happened.
"""
import time

import jax
import numpy as np
import pytest

from repro.core.generation import SlotAllocationError
from repro.models import registry as R
from repro.serving import (
    AdmissionRefused,
    FaultError,
    FaultPlan,
    FaultSpec,
    LoopbackTransport,
    NDIFClient,
    NDIFServer,
    RetryPolicy,
    TicketError,
    TransportError,
)
from repro.serving import faults
from repro.serving.stream import StreamChannel, assemble_result, check_frames


# ------------------------------------------------------------- unit layer
def _pattern(seed):
    """Fire a fixed hit sequence against a plan; return the fire bitmap."""
    plan = FaultPlan(
        [
            FaultSpec("decode.step", nth=3),
            FaultSpec("engine.tick", every=2, max_fires=None),
            FaultSpec("page.alloc", p=0.5, max_fires=None),
            FaultSpec("prefill.dispatch", nth=2, every=3, max_fires=None),
        ],
        seed=seed,
    )
    fired = []
    for _ in range(12):
        for pt in ("decode.step", "engine.tick", "page.alloc",
                   "prefill.dispatch"):
            try:
                plan.fire(pt)
                fired.append(0)
            except FaultError:
                fired.append(1)
    return fired, plan.snapshot()


def test_fault_plan_schedules_are_deterministic():
    f1, s1 = _pattern(7)
    f2, s2 = _pattern(7)
    assert f1 == f2 and s1 == s2          # same seed => same fault sequence
    f3, _ = _pattern(8)
    assert f3 != f1                       # p-spec stream differs by seed
    # nth=3, max_fires=1 (default): exactly one fire, on hit 3
    steps = f1[0::4]
    assert steps == [0, 0, 1] + [0] * 9
    # every=2, uncapped: every second hit
    ticks = f1[1::4]
    assert ticks == [1 if (h + 1) % 2 == 0 else 0 for h in range(12)]
    # nth=2 then every 3rd: hits 2, 5, 8, 11
    prefills = f1[3::4]
    assert [h + 1 for h, x in enumerate(prefills) if x] == [2, 5, 8, 11]
    # the probabilistic spec fired at least once across 12 draws at p=.5
    assert sum(f1[2::4]) >= 1
    assert s1["total_fired"] == sum(f1)


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultSpec("bogus.point", nth=1)
    with pytest.raises(ValueError, match="no schedule"):
        FaultSpec("decode.step")


def test_install_is_gated_but_inject_is_not(monkeypatch):
    plan = FaultPlan([FaultSpec("decode.step", nth=1)])
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert not faults.enabled()
    with pytest.raises(RuntimeError, match="REPRO_FAULTS"):
        faults.install(plan)
    assert faults.active() is None
    # inject() is the explicit scoped opt-in: works with the env unset,
    # and ALWAYS disarms — even when the body raises
    with pytest.raises(FaultError):
        with faults.inject(plan):
            assert faults.active() is plan
            faults.fire("decode.step")
    assert faults.active() is None
    faults.fire("decode.step")  # disarmed: a pure no-op
    monkeypatch.setenv("REPRO_FAULTS", "on")
    assert faults.enabled()
    faults.install(plan)
    try:
        assert faults.active() is plan
    finally:
        faults.uninstall()
    assert faults.active() is None


def test_channel_history_cursor_and_idempotent_final():
    chan = StreamChannel("t")
    chan.push("tokens", {"tokens": np.zeros((1, 1))})
    assert chan.push_final_once("done", {}) is not None
    # a racing second terminal push (watchdog vs engine thread) is dropped
    assert chan.push_final_once("error", {"error": "x"}) is None
    chunks, done = chan.read_since(0)
    assert done and [c.seq for c in chunks] == [0, 1]
    assert chunks[-1].kind == "done"
    # cursor reads are NON-consuming: the same cursor re-delivers
    again, done = chan.read_since(0)
    assert done and [c.seq for c in again] == [0, 1]
    tail, _ = chan.read_since(1)
    assert [c.seq for c in tail] == [1]


def test_retry_policy_is_seeded_and_honors_hint():
    a = RetryPolicy(seed=3)
    b = RetryPolicy(seed=3)
    assert [a.delay_ms(i) for i in range(4)] == [
        b.delay_ms(i) for i in range(4)
    ]
    assert RetryPolicy(seed=1).delay_ms(0, retry_after_ms=5000.0) >= 5000.0


# ------------------------------------------------------------- live layer
@pytest.fixture(scope="module")
def live():
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    toks = np.asarray(
        jax.random.randint(jax.random.key(2), (1, 6), 0, cfg.vocab_size)
    )
    servers = []

    def make(*, retry=None, **host_kw):
        host_kw.setdefault("num_slots", 4)
        host_kw.setdefault("slot_max_len", 64)
        host_kw.setdefault("max_queue_depth", 16)
        server = NDIFServer()
        server.host("m", model, params, policy="continuous", **host_kw)
        client = NDIFClient(LoopbackTransport(server.handle), "m",
                            retry=retry)
        server._frontdoor("m")  # eager: thread-leak baseline counts it
        servers.append(server)
        return server, client

    # the shared door most tests ride: generous restart budget so the
    # crash tests stay independent, fast backoff, no quarantine surprises
    server, client = make(door_kwargs=dict(
        max_restarts=100, restart_backoff_s=0.01, quarantine_after=5,
    ))
    yield {"make": make, "server": server, "client": client, "toks": toks}
    for s in servers:
        s.shutdown()


def test_transport_fault_without_retry_raises(live):
    client, toks = live["client"], live["toks"]
    plan = FaultPlan(
        [FaultSpec("transport.send", nth=1, error=TransportError)], seed=0
    )
    with faults.inject(plan), pytest.raises(TransportError):
        client.submit(toks, 4)
    assert plan.fires() == 1


def test_retry_and_idempotency_survive_lost_messages(live):
    """Lost request (safe) THEN lost reply (ambiguous): the retrying
    client converges on ONE server-side execution via its idempotency
    key, and the result is bit-exact."""
    server, toks = live["server"], live["toks"]
    stats = server.engines["m"].stats
    rclient = NDIFClient(
        LoopbackTransport(server.handle), "m",
        retry=RetryPolicy(max_attempts=5, base_delay_ms=1.0, seed=1),
    )
    ref = rclient.generate(toks, 6)["tokens"]
    before = len(stats.snapshot()["tickets"])
    plan = FaultPlan(
        [
            # roundtrip 1 (submit): request lost before the server saw it
            FaultSpec("transport.send", nth=1, error=TransportError),
            # roundtrip 2 (retry): server ADMITS, then the reply is lost
            FaultSpec("transport.recv", nth=1, error=TransportError),
        ],
        seed=0, stats=stats,
    )
    with faults.inject(plan):
        tk = rclient.submit(toks, 6)
        out = tk.result(timeout=600.0)
    assert plan.fires() == 2
    np.testing.assert_array_equal(out["tokens"], ref)
    # the ambiguous retry deduped: exactly ONE ticket executed
    assert len(stats.snapshot()["tickets"]) == before + 1


def test_engine_crash_recovery_is_bit_exact(live):
    """A decode-window crash mid-flight: the supervisor rebuilds the
    loop, requeues every in-flight ticket, and deterministic re-execution
    makes results — including an already-streaming ticket — bit-exact."""
    server, client, toks = live["server"], live["client"], live["toks"]
    stats = server.engines["m"].stats
    before = stats.snapshot()
    ref = client.generate(toks, 12)["tokens"]
    plan = FaultPlan(
        [FaultSpec("decode.step", nth=2, error=FaultError,
                   message="injected engine crash")],
        seed=0, stats=stats,
    )
    with faults.inject(plan):
        tks = [client.submit(toks, 12) for _ in range(2)]
        tks.append(client.submit(toks, 12, stream=True))
        outs = [t.result(timeout=600.0) for t in tks]
    assert plan.fires() == 1
    for out in outs:
        np.testing.assert_array_equal(out["tokens"], ref)
    after = stats.snapshot()
    assert after["engine_restarts"] == before["engine_restarts"] + 1
    # every ticket ADMITTED by crash time is requeued; stragglers still in
    # the inbox ride the normal admission path instead (timing-dependent)
    requeued = after["tickets_requeued"] - before["tickets_requeued"]
    assert 1 <= requeued <= 3
    assert after["faults_injected"] == before["faults_injected"] + 1


def test_page_alloc_fault_requeues_admission(live):
    """Page-pool exhaustion at admission is NOT a crash: the scheduler
    requeues the admission and the next boundary succeeds."""
    server, client, toks = live["server"], live["client"], live["toks"]
    stats = server.engines["m"].stats
    before = stats.snapshot()
    ref = client.generate(toks, 6)["tokens"]
    plan = FaultPlan(
        [FaultSpec("page.alloc", nth=1, error=SlotAllocationError)],
        seed=0, stats=stats,
    )
    with faults.inject(plan):
        out = client.submit(toks, 6).result(timeout=600.0)
    assert plan.fires() == 1
    np.testing.assert_array_equal(out["tokens"], ref)
    after = stats.snapshot()
    assert after["alloc_retries"] == before["alloc_retries"] + 1
    assert after["engine_restarts"] == before["engine_restarts"]


def test_deadline_eviction_frees_pages_and_spares_cotenant(live):
    server, client, toks = live["server"], live["client"], live["toks"]
    stats = server.engines["m"].stats
    before = stats.snapshot()
    door = server.frontdoors["m"]
    deadline = time.time() + 30.0
    while (door.loop.resident or door.queue_depth()) \
            and time.time() < deadline:
        time.sleep(0.02)
    pages_before = len(door.loop._free_pages)
    ref = client.generate(toks, 6)["tokens"]
    # a pure latency spike on the first decode window guarantees the
    # doomed ticket is resident past its budget, deterministically
    plan = FaultPlan(
        [FaultSpec("decode.step", nth=1, delay_s=0.4, error=None)], seed=0
    )
    with faults.inject(plan):
        doomed = client.submit(toks, 40, deadline_ms=150.0)
        ok = client.submit(toks, 6)
        out = ok.result(timeout=600.0)
        with pytest.raises(TicketError) as ei:
            doomed.result(timeout=600.0)
    assert ei.value.code == "deadline"
    np.testing.assert_array_equal(out["tokens"], ref)
    after = stats.snapshot()
    assert after["deadline_evictions"] == before["deadline_evictions"] + 1
    # the evicted ticket's rows AND reserved KV pages came back
    deadline = time.time() + 30.0
    while (door.loop.resident or door.queue_depth()) \
            and time.time() < deadline:
        time.sleep(0.02)
    assert len(door.loop._free_pages) == pages_before


def test_cancel_kills_ticket_with_structured_error(live):
    server, client, toks = live["server"], live["client"], live["toks"]
    stats = server.engines["m"].stats
    before = stats.snapshot()
    tk = client.submit(toks, 40)
    assert tk.cancel() is True
    with pytest.raises(TicketError) as ei:
        tk.result(timeout=600.0)
    assert ei.value.code == "cancelled"
    assert tk.cancel() is False  # already terminated: result stands
    after = stats.snapshot()
    assert after["cancellations"] == before["cancellations"] + 1


def test_poll_redelivery_after_done(live):
    """take(since=0) re-reads the FULL chunk history even after the
    ticket completed — a lost poll reply is never data loss."""
    server, client, toks = live["server"], live["client"], live["toks"]
    tk = client.submit(toks, 4)
    out = tk.result(timeout=600.0)
    door = server.frontdoors["m"]
    chunks1, done1 = door.take(tk.id, since=0)
    chunks2, done2 = door.take(tk.id, since=0)
    assert done1 and done2
    assert [c["seq"] for c in chunks1] == [c["seq"] for c in chunks2]
    check_frames(chunks1, tk.id)
    result, _logs = assemble_result(chunks1)
    np.testing.assert_array_equal(result["tokens"], out["tokens"])


def test_fused_compile_fault_degrades_to_eager(live):
    """A compile failure for one fused window size degrades THAT window
    to eager stepping — bit-exact, no restart, door stays healthy."""
    make, toks = live["make"], live["toks"]
    server, client = make()
    try:
        stats = server.engines["m"].stats
        plan = FaultPlan(
            [FaultSpec("fused.compile", nth=1, error=FaultError)],
            seed=0, stats=stats,
        )
        with faults.inject(plan):
            out = client.submit(toks, 6).result(timeout=600.0)
        ref = client.generate(toks, 6)["tokens"]
        np.testing.assert_array_equal(out["tokens"], ref)
        assert plan.fires() == 1
        assert stats.engine_restarts == 0
    finally:
        server.shutdown()


def test_restart_budget_exhaustion_fails_door_cleanly(live):
    """A persistent crash loop exhausts max_restarts: every pending
    ticket gets a terminal structured error, later submissions are
    refused with the same code, close() does NOT raise."""
    make, toks = live["make"], live["toks"]
    server, client = make(door_kwargs=dict(
        # quarantine_after above the budget: the offender must keep
        # requeueing so the RESTART budget (not quarantine) ends the loop
        max_restarts=2, restart_backoff_s=0.01, quarantine_after=99,
    ))
    try:
        stats = server.engines["m"].stats
        plan = FaultPlan(
            [FaultSpec("decode.step", every=1, max_fires=None,
                       error=FaultError)],
            seed=0, stats=stats,
        )
        with faults.inject(plan):
            tk = client.submit(toks, 6)
            with pytest.raises(TicketError) as ei:
                tk.result(timeout=600.0)
            assert ei.value.code == "engine_failed"
            with pytest.raises(AdmissionRefused) as ar:
                client.submit(toks, 6)
            assert ar.value.code == "engine_failed"
        assert stats.engine_restarts == 3  # budget 2 + the failing crash
    finally:
        server.shutdown()  # supervised failure: shutdown must not raise


def test_repeat_offender_is_quarantined(live):
    """A ticket resident across quarantine_after crashes is failed with
    code="engine_restart" instead of riding the requeue forever; the
    door then serves fresh work normally."""
    make, toks = live["make"], live["toks"]
    server, client = make(door_kwargs=dict(
        max_restarts=10, restart_backoff_s=0.01, quarantine_after=2,
    ))
    try:
        stats = server.engines["m"].stats
        plan = FaultPlan(
            [FaultSpec("decode.step", every=1, max_fires=2,
                       error=FaultError)],
            seed=0, stats=stats,
        )
        with faults.inject(plan):
            tk = client.submit(toks, 6)
            with pytest.raises(TicketError) as ei:
                tk.result(timeout=600.0)
        assert ei.value.code == "engine_restart"
        assert stats.engine_restarts == 2
        # the door survived — fresh work completes bit-exact
        out = client.submit(toks, 6).result(timeout=600.0)
        ref = client.generate(toks, 6)["tokens"]
        np.testing.assert_array_equal(out["tokens"], ref)
    finally:
        server.shutdown()


def test_backpressure_retry_after_is_clamped_with_position(live):
    make, toks = live["make"], live["toks"]
    server, client = make(
        num_slots=2, max_queue_depth=2,
        door_kwargs=dict(retry_after_bounds=(25.0, 40.0)),
    )
    try:
        refusal = None
        for _ in range(50):
            try:
                client.submit(toks, 32)
            except AdmissionRefused as e:
                refusal = e
                break
        assert refusal is not None and refusal.code == "backpressure"
        assert 25.0 <= refusal.retry_after_ms <= 40.0
        assert refusal.payload["position"] >= 1
    finally:
        server.shutdown()


def test_watchdog_detects_stuck_step(live):
    """A stall INSIDE the engine loop (thread alive, heartbeat frozen)
    trips the watchdog: blocked pollers get code="engine_stalled"
    immediately, submissions are refused, close() stays clean."""
    make, toks = live["make"], live["toks"]
    server, client = make(door_kwargs=dict(stall_timeout_s=30.0))
    try:
        # warm the door under a generous threshold (XLA compiles must not
        # look like the stall), then tighten it — the watchdog re-reads
        # the threshold every period
        client.submit(toks, 6).result(timeout=600.0)
        server.frontdoors["m"].stall_timeout_s = 0.25
        plan = FaultPlan(
            [FaultSpec("engine.tick", nth=1, delay_s=2.0, error=None)],
            seed=0,
        )
        with faults.inject(plan):
            tk = client.submit(toks, 6)
            with pytest.raises(TicketError) as ei:
                tk.result(timeout=600.0)
            assert ei.value.code == "engine_stalled"
            with pytest.raises(AdmissionRefused) as ar:
                client.submit(toks, 6)
            assert ar.value.code == "engine_stalled"
        assert plan.fires() == 1
    finally:
        server.shutdown()


def test_stats_wire_kind_carries_fault_counters(live):
    client = live["client"]
    snap = client.stats()
    for key in ("faults_injected", "engine_restarts", "tickets_requeued",
                "cancellations", "deadline_evictions"):
        assert key in snap and snap[key] >= 0
