"""Live front door: engine thread, streaming, backpressure, shutdown.

Everything here drives the REAL threaded :class:`FrontDoor` through the
wire protocol (submit/poll/stream kinds over a LoopbackTransport) — no
mocked channels.  Determinism: arrivals are seeded, and every numeric
assertion is bit-exactness against the synchronous solo path (fused
window splits are bit-identical, so chunked streams must concatenate to
the exact solo tokens).
"""
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.serialize import decode_value, encode_value
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import (
    AdmissionRefused,
    LoopbackTransport,
    NDIFClient,
    NDIFServer,
)
from repro.serving.stream import StreamChannel, assemble_result, check_frames


@pytest.fixture(scope="module")
def live():
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host("m", model, params, policy="continuous", num_slots=4,
                slot_max_len=64, max_queue_depth=8)
    transport = LoopbackTransport(server.handle)
    client = NDIFClient(transport, "m")
    # create the door (and its engine thread) EAGERLY so the per-test
    # thread-leak fixture's baseline already includes it
    server._frontdoor("m")
    toks = np.asarray(
        jax.random.randint(jax.random.key(1), (1, 6), 0, cfg.vocab_size)
    )
    yield cfg, model, params, server, transport, client, toks
    server.shutdown()


# --------------------------------------------------------------- unit layer
def test_stream_channel_framing_and_blocking():
    chan = StreamChannel("t0")
    got = []

    def consumer():
        while True:
            chunks, done = chan.get(timeout=5.0)
            got.extend(chunks)
            if done:
                return

    t = threading.Thread(target=consumer)
    t.start()
    chan.push("tokens", {"tokens": np.zeros((1, 2))})
    chan.push("saves", {"h": np.ones(3)})
    chan.push("done", {}, final=True)
    t.join(10.0)
    assert not t.is_alive()
    check_frames([c.to_wire() for c in got], "t0")
    assert [c.kind for c in got] == ["tokens", "saves", "done"]
    with pytest.raises(RuntimeError, match="closed"):
        chan.push("tokens", {})


def test_check_frames_catches_corruption():
    ok = [{"ticket": 1, "seq": 0, "kind": "done", "payload": {},
           "final": True}]
    check_frames(ok, 1)
    with pytest.raises(ValueError, match="delivered to"):
        check_frames(ok, 2)
    torn = [{"ticket": 1, "seq": 1, "kind": "done", "payload": {},
             "final": True}]
    with pytest.raises(ValueError, match="seq"):
        check_frames(torn, 1)


# -------------------------------------------------------------- happy paths
def test_batch_submit_bit_exact(live):
    cfg, model, params, server, transport, client, toks = live
    ref = client.generate(toks, 8)
    ticket = client.submit(toks, 8)
    res = ticket.result()
    np.testing.assert_array_equal(res["tokens"], ref["tokens"])
    np.testing.assert_array_equal(res["logits"], ref["logits"])


def test_streamed_chunks_concatenate_bit_exact(live):
    cfg, model, params, server, transport, client, toks = live
    ref = client.generate(toks, 8)
    ticket = client.submit(toks, 8, stream=True)
    kinds = [c["kind"] for c in ticket.chunks()]
    assert kinds.count("tokens") >= 2, kinds  # actually incremental
    assert kinds[-1] == "done"
    res = ticket.result()
    np.testing.assert_array_equal(res["tokens"], ref["tokens"])
    np.testing.assert_array_equal(res["logits"], ref["logits"])


def test_streaming_saves_and_logs_flush_incrementally(live):
    cfg, model, params, server, transport, client, toks = live
    from repro.core.graph import InterventionGraph, Ref

    g = InterventionGraph()
    tap = g.add("tap_get", site="layers.output", layer=2, step=0)
    g.mark_saved("h2", g.add("save", Ref(tap.id)))
    lgt = g.add("tap_get", site="logits", step=1)
    g.add("log", Ref(lgt.id), step=1)
    ticket = client.submit(toks, 6, graph=g, stream=True)
    chunks = list(ticket.chunks())
    kinds = [c["kind"] for c in chunks]
    assert "saves" in kinds and "logs" in kinds, kinds
    res = ticket.result()
    ref = client.generate(toks, 6, graph=g)
    np.testing.assert_array_equal(res["tokens"], ref["tokens"])
    np.testing.assert_allclose(np.asarray(res["h2"]),
                               np.asarray(ref["h2"]), rtol=1e-5)


def test_single_forward_trace_through_front_door(live):
    cfg, model, params, server, transport, client, toks = live
    from repro.core.graph import InterventionGraph, Ref

    g = InterventionGraph()
    tap = g.add("tap_get", site="logits")
    g.mark_saved("out", g.add("save", Ref(tap.id)))
    ticket = client.submit(batch={"tokens": toks}, graph=g)
    res = ticket.result()
    lm = traced_lm(model, params)
    with lm.trace(toks):
        out = lm.output.save("out")
    np.testing.assert_allclose(np.asarray(res["out"]),
                               np.asarray(out.value), rtol=1e-4, atol=1e-4)


# ------------------------------------------------- concurrency / determinism
def test_concurrent_submitters_never_corrupt_frames(live):
    """N client threads submit + poll concurrently; every ticket's chunk
    sequence must frame-check (gapless seq, no cross-ticket chunks) and
    assemble bit-exact to the solo result."""
    cfg, model, params, server, transport, client, toks = live
    n_threads, n_new = 6, 6
    ref = client.generate(toks, n_new)["tokens"]
    rng = np.random.default_rng(7)
    delays = rng.uniform(0.0, 0.05, n_threads)
    results: dict[int, np.ndarray] = {}
    errors: list[str] = []

    def worker(i):
        try:
            time.sleep(delays[i])
            tk = client.submit(toks, n_new, stream=(i % 2 == 0))
            res = tk.result(timeout=300.0)  # frame-checks internally
            results[i] = res["tokens"]
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(f"worker {i}: {type(e).__name__}: {e}")

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(300.0)
    assert not errors, errors
    assert sorted(results) == list(range(n_threads))
    for i in range(n_threads):
        np.testing.assert_array_equal(results[i], ref)


def test_poisson_smoke_load(live):
    """Capstone smoke (tier-1 twin of benchmarks/live_serving.py): seeded
    Poisson arrivals from many client threads through the real threaded
    front door; all admitted tickets complete bit-exact."""
    cfg, model, params, server, transport, client, toks = live
    n_clients, n_new = 24, 4
    ref = client.generate(toks, n_new)["tokens"]
    rng = np.random.default_rng(11)
    arrivals = np.cumsum(rng.exponential(0.02, n_clients))
    results: dict[int, np.ndarray] = {}
    refused: list[int] = []
    errors: list[str] = []
    t0 = time.perf_counter()

    def worker(i):
        delay = arrivals[i] - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        for attempt in range(200):
            try:
                tk = client.submit(toks, n_new, stream=(i % 3 == 0))
            except AdmissionRefused as e:
                refused.append(i)
                assert e.code == "backpressure"
                assert e.retry_after_ms is not None
                time.sleep(e.retry_after_ms / 1000.0)
                continue
            except Exception as e:  # pragma: no cover
                errors.append(f"worker {i}: {type(e).__name__}: {e}")
                return
            try:
                results[i] = tk.result(timeout=600.0)["tokens"]
            except Exception as e:  # pragma: no cover
                errors.append(f"worker {i}: {type(e).__name__}: {e}")
            return
        errors.append(f"worker {i}: starved after 200 refusals")

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(600.0)
    assert not errors, errors
    assert sorted(results) == list(range(n_clients))
    for i in range(n_clients):
        np.testing.assert_array_equal(results[i], ref)
    stats = client.stats()
    # bounded backlog: high-water depth never exceeded the configured cap
    assert stats["queue_depth_max"] <= 8
    assert stats["stream_chunks"] > 0
    tix = stats["tickets"]
    assert len(tix) >= n_clients
    assert all(t["time_to_first_token"] is not None for t in tix
               if t["status"] == "ok")


# ------------------------------------------------------------- admission
def test_backpressure_structured_refusal(live):
    cfg, model, params, server, transport, client, toks = live
    before = client.stats()["rejected_submissions"]
    tickets, refusals = [], []
    for _ in range(40):
        try:
            tickets.append(client.submit(toks, 12))
        except AdmissionRefused as e:
            refusals.append(e)
    assert refusals, "queue cap never triggered"
    e = refusals[0]
    assert e.code == "backpressure"
    assert e.payload["max_queue_depth"] == 8
    assert e.payload["queue_depth"] >= 8
    assert e.retry_after_ms and e.retry_after_ms > 0
    for tk in tickets:  # drain so later tests start clean
        tk.result(timeout=600.0)
    assert client.stats()["rejected_submissions"] > before


def test_capacity_refusal_is_pages_aware(live):
    cfg, model, params, server, transport, client, toks = live
    long = np.tile(toks, (1, 10))  # 60 prompt tokens + 120 new > max_len 64
    with pytest.raises(AdmissionRefused) as ei:
        client.submit(long, 120)
    assert ei.value.code == "capacity"


def test_slo_refusal_uses_measured_costs(live):
    cfg, model, params, server, transport, client, toks = live
    assert client.stats()["step_cost_ema"] > 0  # earlier tests warmed it
    with pytest.raises(AdmissionRefused) as ei:
        client.submit(toks, 8, slo_ms=0.001)
    assert ei.value.code == "slo"
    assert ei.value.payload["projected_ms"] > ei.value.payload["slo_ms"]
    # a sane budget admits
    tk = client.submit(toks, 4, slo_ms=600_000.0)
    assert tk.result(timeout=300.0)["tokens"].shape == (1, 4)


def test_stats_carry_frontdoor_counters(live):
    cfg, model, params, server, transport, client, toks = live
    s = client.stats()
    for key in ("queue_depth", "queue_depth_max", "rejected_submissions",
                "stream_chunks", "step_cost_ema", "prefill_cost_ema",
                "tickets"):
        assert key in s, key
    rec = s["tickets"][-1]
    assert {"queue_wait", "time_to_first_token", "response_time",
            "status"} <= set(rec)


# --------------------------------------------------------------- shutdown
def test_close_drains_rejects_and_joins():
    """Clean shutdown on a PRIVATE server: resident work completes, queued
    work is rejected with a structured error, the engine thread joins —
    no thread leaks into the rest of the suite."""
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host("m", model, params, policy="continuous", num_slots=2,
                slot_max_len=64, max_queue_depth=16)
    client = NDIFClient(LoopbackTransport(server.handle), "m")
    toks = np.asarray(
        jax.random.randint(jax.random.key(2), (1, 6), 0, cfg.vocab_size)
    )
    ref = client.generate(toks, 6)["tokens"]
    before = threading.active_count()
    tickets = [client.submit(toks, 6) for _ in range(6)]
    door = server.frontdoors["m"]
    deadline = time.perf_counter() + 60.0
    while not door.loop.resident and time.perf_counter() < deadline:
        time.sleep(0.01)  # close() races admission otherwise: with no
        # residents yet, EVERY ticket gets the structured rejection
    assert door.loop.resident
    server.shutdown()
    assert not door._thread.is_alive()
    assert threading.active_count() <= before  # engine thread joined
    outcomes = {"ok": 0, "closed": 0}
    for tk in tickets:
        try:
            np.testing.assert_array_equal(
                tk.result(timeout=30.0)["tokens"], ref
            )
            outcomes["ok"] += 1
        except RuntimeError as e:
            assert "closed" in str(e)
            outcomes["closed"] += 1
    assert outcomes["ok"] >= 1  # residents drained to completion
    with pytest.raises(AdmissionRefused) as ei:
        client.submit(toks, 4)
    assert ei.value.code == "closed"


def _private_door(num_slots=2, max_queue_depth=16, key=2):
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host("m", model, params, policy="continuous",
                num_slots=num_slots, slot_max_len=64,
                max_queue_depth=max_queue_depth)
    client = NDIFClient(LoopbackTransport(server.handle), "m")
    server._frontdoor("m")
    toks = np.asarray(
        jax.random.randint(jax.random.key(key), (1, 6), 0, cfg.vocab_size)
    )
    return server, client, toks


def test_close_races_submit():
    """close() from one thread while another spam-submits: every submit
    either returns a ticket that TERMINATES (result or structured error)
    or raises the structured ``closed`` refusal — never a hang, never an
    unstructured crash."""
    server, client, toks = _private_door()
    tickets, refusals, errors = [], [], []
    stop = threading.Event()

    def submitter():
        while not stop.is_set():
            try:
                tickets.append(client.submit(toks, 4))
            except AdmissionRefused as e:
                refusals.append(e.code)
                if e.code == "closed":
                    return
                time.sleep(0.005)
            except Exception as e:  # pragma: no cover - failure reporting
                errors.append(f"{type(e).__name__}: {e}")
                return

    t = threading.Thread(target=submitter)
    t.start()
    time.sleep(0.3)  # let some submissions land mid-flight
    server.shutdown()
    stop.set()
    t.join(30.0)
    assert not t.is_alive()
    assert not errors, errors
    for tk in tickets:  # every admitted ticket terminates, one way or another
        try:
            tk.result(timeout=60.0)
        except RuntimeError as e:
            assert "closed" in str(e)
    assert "closed" in refusals or tickets


def test_close_races_inflight_fused_window():
    """close() issued while a fused decode window is mid-flight on the
    engine thread: the resident drains to completion and its result stays
    bit-exact — closing never tears a window."""
    server, client, toks = _private_door(key=3)
    ref = client.generate(toks, 12)["tokens"]
    tk = client.submit(toks, 12)
    door = server.frontdoors["m"]
    deadline = time.perf_counter() + 60.0
    while not door.loop.resident and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert door.loop.resident
    # no boundary sync: close lands while the engine thread is stepping
    server.shutdown()
    np.testing.assert_array_equal(tk.result(timeout=60.0)["tokens"], ref)


# ----------------------------------------------------- satellite: log fix
def test_jit_single_forward_trace_keeps_logs():
    """PR 8 residual: the jitted single-forward path dropped log()
    values.  They must survive locally, on the compiled-cache-hit rerun,
    and over the wire."""
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    toks = np.asarray(
        jax.random.randint(jax.random.key(3), (2, 8), 0, cfg.vocab_size)
    )
    lm = traced_lm(model, params)
    with lm.trace(toks) as tr:
        tr.log(lm.layers[2].output.mean())
        lm.output.save("out")
    assert len(tr.logs) == 1
    with lm.trace(toks) as tr2:  # compiled-executable cache hit
        tr2.log(lm.layers[2].output.mean())
        lm.output.save("out")
    assert len(tr2.logs) == 1, "cache-hit execution dropped log()"
    np.testing.assert_allclose(np.asarray(tr2.logs[0][1]),
                               np.asarray(tr.logs[0][1]), rtol=1e-6)

    server = NDIFServer()
    server.host("m", model, params, policy="parallel")
    client = NDIFClient(LoopbackTransport(server.handle), "m")
    lmr = traced_lm(model, None, backend=client)
    with lmr.trace(toks, remote=True) as trr:
        trr.log(lmr.layers[2].output.mean())
        out = lmr.output.save("out")
    assert len(trr.logs) == 1
    np.testing.assert_allclose(np.asarray(trr.logs[0][1]),
                               np.asarray(tr.logs[0][1]), rtol=1e-5)
    assert out.value is not None  # the reserved key never shadowed saves


def test_transport_session_meters_both_ways(live):
    cfg, model, params, server, transport, client, toks = live
    base_req = transport.stats.requests
    sess = transport.session()
    msg = {"kind": "stats", "model": "m"}
    payload = json.dumps(encode_value(msg), separators=(",", ":")).encode()
    reply = decode_value(json.loads(sess.request(payload).decode()))
    assert reply["ok"]
    assert sess.stats.requests == 1
    assert sess.stats.bytes_sent == len(payload) > 0
    assert sess.stats.bytes_received > 0
    assert transport.stats.requests == base_req + 1
    sess.close()
    with pytest.raises(RuntimeError, match="closed"):
        sess.request(payload)
