"""End-to-end behaviour tests for the whole system (paper workflow).

The canonical NNsight/NDIF loop: write research code against the tracing
API -> graph is serialized -> shipped to a shared server hosting a preloaded
model -> interleaved server-side -> only .save()d values come back.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # end-to-end zoo loop: minutes on CPU

from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer


@pytest.fixture(scope="module")
def system():
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="parallel")
    transport = LoopbackTransport(server.handle)
    client = NDIFClient(transport, cfg.name)
    return cfg, model, params, server, transport, client


def test_figure3_neuron_intervention(system):
    """Paper Fig. 3b: set three 'neurons' at an MLP output, read the flip."""
    cfg, model, params, server, transport, client = system
    lm = traced_lm(model, None, backend=client)
    toks = np.asarray(
        jax.random.randint(jax.random.key(3), (1, 8), 0, cfg.vocab_size)
    )
    neurons = [3, 17, 41]
    with lm.trace(toks, remote=True):
        base = lm.output.save("base")
    with lm.trace(toks, remote=True):
        lm.layers[4].mlp.output[:, -1, neurons] = 10.0
        out = lm.output.save("out")
    b, o = np.asarray(base.value), np.asarray(out.value)
    assert b.shape == o.shape == (1, 8, cfg.vocab_size)
    assert not np.allclose(b[:, -1], o[:, -1])  # intervention took effect
    np.testing.assert_allclose(b[:, :3], o[:, :3], atol=1e-4)  # causal: past unchanged


def test_code_example_2_3_activation_patching(system):
    """Paper Code Example 3: patch base prompt with edit prompt state."""
    cfg, model, params, server, transport, client = system
    lm = traced_lm(model, None, backend=client)
    rng = np.random.default_rng(0)
    batch = rng.integers(0, cfg.vocab_size, (2, 9)).astype(np.int32)
    edit_tok, base_tok = 5, 6
    with lm.trace(batch, remote=True):
        lm.layers[5].output[1, base_tok, :] = lm.layers[5].output[0, edit_tok, :]
        out = lm.output.save("out")
    # locally verify against non-remote execution
    lm_local = traced_lm(model, params)
    with lm_local.trace(jnp.asarray(batch)):
        lm_local.layers[5].output[1, base_tok, :] = \
            lm_local.layers[5].output[0, edit_tok, :]
        expect = lm_local.output.save("out")
    np.testing.assert_allclose(np.asarray(out.value),
                               np.asarray(expect.value), rtol=1e-4, atol=1e-4)


def test_attribution_patching_grads(system):
    """Paper Code Example 4: hidden states AND their grads in one trace."""
    cfg, model, params, *_ = system
    lm = traced_lm(model, params)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32))
    targets = jnp.asarray(rng.integers(0, cfg.vocab_size, (2,)).astype(np.int32))
    with lm.trace(toks) as tr:
        h = lm.layers[3].output.save("h")
        g = lm.layers[3].output.grad.save("g")
        logits = lm.output
        nll = tr.apply("nll")(logits[:, -1, :], targets).sum().save("loss")
        tr.backward(nll)
    assert np.asarray(tr.result("h")).shape == (2, 8, cfg.d_model)
    assert np.asarray(tr.result("g")).shape == (2, 8, cfg.d_model)
    assert np.abs(np.asarray(tr.result("g"))).sum() > 0


def test_remote_probe_training_pattern(system):
    """Paper Code Example 8 (simplified): collect layer-0/layer-1 pairs
    remotely, fit a linear probe locally, verify loss decreases."""
    cfg, model, params, server, transport, client = system
    lm = traced_lm(model, None, backend=client)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (4, 8)).astype(np.int32)
    with lm.trace(toks, remote=True):
        h0 = lm.layers[0].output.save("h0")
        h1 = lm.layers[1].output.save("h1")
    X = np.asarray(h0.value).reshape(-1, cfg.d_model)
    Y = np.asarray(h1.value).reshape(-1, cfg.d_model)

    def loss(W):
        return float(np.mean((X @ W - Y) ** 2))

    l0 = loss(np.zeros((cfg.d_model, cfg.d_model)))
    W, *_ = np.linalg.lstsq(X, Y, rcond=None)
    assert loss(W) < 0.5 * l0


def test_wire_format_is_json(system):
    """The request payload is valid JSON (paper: 'serialized to a custom
    JSON format')."""
    cfg, model, params, server, transport, client = system
    captured = {}
    orig = transport.handler

    def spy(payload):
        captured["payload"] = payload
        return orig(payload)

    transport.handler = spy
    try:
        lm = traced_lm(model, None, backend=client)
        toks = np.zeros((1, 4), np.int32)
        with lm.trace(toks, remote=True):
            lm.layers[0].output.save("x")
    finally:
        transport.handler = orig
    msg = json.loads(captured["payload"].decode())
    assert msg["kind"] == "trace"
    assert msg["graph"]["version"] == 1
    assert all(isinstance(n["op"], str) for n in msg["graph"]["nodes"])


def test_scan_validation_catches_shape_bug(system):
    """The paper's FakeTensor 'scanning' analogue: eval_shape validation
    flags a bad intervention before any compute."""
    cfg, model, params, *_ = system
    lm = traced_lm(model, params)
    toks = np.zeros((1, 4), np.int32)
    with pytest.raises(Exception):
        with lm.trace(jnp.asarray(toks), scan=True) as tr:
            bad = tr.constant(np.ones((3, 3), np.float32))
            lm.layers[0].output = bad  # wrong shape for the site
            lm.output.save("x")
