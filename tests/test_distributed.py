"""Sharding helpers: spec sanitization, FSDP widening, batch specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sanitize_spec, shard_hint, use_mesh
from repro.models import registry as R
from repro.models.registry import batch_pspecs, fsdp_pspecs, param_pspecs


@pytest.fixture(scope="module")
def mesh2d():
    # degenerate 1x1 mesh over the single CPU device — shape rules still run
    return jax.make_mesh((1, 1), ("data", "model"))


def test_sanitize_drops_unknown_axes(mesh2d):
    spec = sanitize_spec(P("pod", "model"), (8, 8), mesh2d)
    assert spec == P(None, "model")


def test_sanitize_drops_indivisible(mesh2d):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 7 % 1 == 0 so nothing dropped on the tiny mesh; simulate with fake dims
    spec = sanitize_spec(P("model"), (7,), mesh)
    assert spec == P("model")


def test_shard_hint_identity_without_mesh():
    x = jnp.ones((4, 4))
    y = shard_hint(x, P("data", "model"))
    np.testing.assert_array_equal(x, y)


def test_shard_hint_with_mesh(mesh2d):
    x = jnp.ones((4, 4))
    with use_mesh(mesh2d):
        y = jax.jit(lambda a: shard_hint(a, P("data", "model")))(x)
    np.testing.assert_array_equal(x, y)


def test_param_pspecs_rules():
    cfg = R.get_config("qwen3-8b", reduced=True)
    model = R.build_model("qwen3-8b", cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = param_pspecs(params)
    # embeddings shard vocab over model
    assert specs["embed"] == P("model", None)
    layer_specs = specs["layers"]
    assert layer_specs["attn"]["wq"]["w"] == P(None, None, "model")
    assert layer_specs["attn"]["wo"]["w"] == P(None, "model", None)
    assert layer_specs["mlp"]["wd"]["w"] == P(None, "model", None)


def test_param_pspecs_moe_expert_parallel():
    cfg = R.get_config("qwen3-moe-30b-a3b", reduced=True)
    model = R.build_model("qwen3-moe-30b-a3b", cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = param_pspecs(params)
    # experts shard over model (leading stacked-layer dim replicated)
    assert specs["layers"]["moe"]["wg"] == P(None, "model", None, None)


def test_fsdp_widening():
    cfg = R.get_config("qwen3-8b", reduced=True)
    model = R.build_model("qwen3-8b", cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    specs = fsdp_pspecs(params, data_axis_size=2)
    # wq (L, d, H*hd): model on dim2 from TP; FSDP adds data on dim1 (d=128
    # divisible by 2)
    assert specs["layers"]["attn"]["wq"]["w"] == P("data", None, "model")


def test_batch_pspecs():
    specs = {
        "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32),
    }
    out = batch_pspecs(specs)
    assert out["tokens"] == P(("pod", "data"), None)


def test_batch_pspecs_cache():
    cfg = R.get_config("qwen3-8b", reduced=True)
    model = R.build_model("qwen3-8b", cfg)
    cache = jax.eval_shape(lambda: model.init_cache(4, 32))
    specs = batch_pspecs(cache)
    # (L, B, T, K, hd): batch on dim1, SEQUENCE on dim2 (flash-decoding
    # sharding, §Perf H2.4 — head counts don't divide the model axis)
    assert specs.data["k"] == P(None, ("pod", "data"), "model", None, None)
    # positions (B, T): KVCache's custom-pytree path has no dict key, so the
    # default batch rule applies (replicated T is fine — it's int32).
    assert specs.positions == P(("pod", "data"), None)


def test_local_mesh_train_step_runs():
    """pjit path exercised end-to-end on the (1,1) local mesh."""
    from repro.distributed import named_sharding
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import make_train_step

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = R.get_config("qwen3-8b", reduced=True)
    model = R.build_model("qwen3-8b", cfg)
    params = model.init(jax.random.key(0))
    init_state, step = make_train_step(
        model, AdamWConfig(warmup_steps=1, total_steps=2), mode="scan")
    state = init_state(params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32),
    }
    with use_mesh(mesh):
        state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
