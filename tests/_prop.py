"""Property-testing shim: use hypothesis when available, else a tiny
seeded-random fallback.

Tier-1 must collect and pass offline, where ``hypothesis`` is not
installed.  Test modules import ``given``/``settings``/``st`` from here::

    from tests._prop import given, settings, st

When hypothesis is importable the real library is re-exported unchanged.
Otherwise the fallback below provides the (small) API surface the suite
uses — ``st.integers``, ``st.floats``, ``st.sampled_from``, ``st.lists``,
``st.tuples``, ``st.composite`` — backed by a deterministically seeded
``random.Random``, running each property ``max_examples`` times.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # type: ignore
    from hypothesis import strategies as st  # type: ignore

    HAVE_HYPOTHESIS = True
except ImportError:

    import random
    import struct

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A sampler: ``draw(rng)`` produces one example."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, width: int = 64, **_kw):
            def draw(rng):
                v = rng.uniform(min_value, max_value)
                if width == 32:
                    # round-trip through f32 so values are exactly
                    # representable, like hypothesis' width=32
                    v = struct.unpack("f", struct.pack("f", v))[0]
                return v

            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size: int = 0, max_size: int = 10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

        @staticmethod
        def composite(fn):
            """``@st.composite`` — the wrapped fn receives a ``draw`` callable."""

            def factory(*args, **kwargs):
                return _Strategy(
                    lambda rng: fn(lambda s: s.draw(rng), *args, **kwargs)
                )

            return factory

    st = _Strategies()

    def settings(max_examples: int = 100, **_ignored):
        """Record run parameters on the test fn (deadline etc. ignored)."""

        def deco(fn):
            # works whether applied above or below @given
            target = getattr(fn, "__wrapped_property__", fn)
            target.__prop_max_examples__ = max_examples
            return fn

        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            # NOTE: no functools.wraps — copying __wrapped__/signature would
            # make pytest treat the strategy-bound params as fixtures.
            def runner(*args, **kwargs):
                n = getattr(fn, "__prop_max_examples__", None)
                n = n or getattr(runner, "__prop_max_examples__", None) or 25
                for i in range(n):
                    rng = random.Random(0xA5EED + 7919 * i)
                    drawn = [s.draw(rng) for s in strategies]
                    drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            runner.__name__ = fn.__name__
            runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.__wrapped_property__ = fn
            return runner

        return deco
