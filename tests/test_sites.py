"""Every declared tap site actually fires, for every architecture family.

This is the invariant the whole paper-technique rests on: the site schedule
IS the intervention surface.  For each reduced arch we build one trace that
saves EVERY site (layer 0 for per-layer sites) and execute it — a site that
never fires raises GraphValidationError in finalize.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import taps
from repro.core.graph import InterventionGraph, Ref
from repro.core.interleave import run_interleaved
from repro.models import registry as R

ARCHS = R.list_archs()


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mode", ["unrolled", "scan"])
def test_every_site_fires(arch, mode):
    cfg = R.get_config(arch, reduced=True)
    model = R.build_model(arch, cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)}
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (2, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    if cfg.arch_type == "audio":
        batch["src_embeds"] = rng.standard_normal(
            (2, cfg.n_source_frames, cfg.d_model)).astype(np.float32)

    schedule = model.site_schedule(mode)
    g = InterventionGraph()
    seen = set()
    for name, layer in schedule.order:
        if name in seen:
            continue  # first occurrence of each site (its earliest layer)
        seen.add(name)
        t = g.add("tap_get", site=name, layer=layer)
        s = g.add("save", Ref(t.id))
        g.mark_saved(f"{name}@{layer}", s)

    def model_fn(p, b):
        return model.forward(p, b, mode=mode)["logits"]

    _, saves, _ = run_interleaved(
        model_fn, g, schedule, (params, batch), {}, mode=mode
    )
    assert len(saves) == len(seen)
    for name, val in saves.items():
        finite = all(np.isfinite(np.asarray(x)).all()
                     for x in jax.tree.leaves(val))
        assert finite, f"{arch}/{mode}: non-finite value at {name}"


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "mamba2-1.3b"])
def test_ssm_state_intervention_changes_output(arch):
    """Setter on the recurrent state — the capability torch hooks lack."""
    cfg = R.get_config(arch, reduced=True)
    model = R.build_model(arch, cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, 8)).astype(np.int32)}
    schedule = model.site_schedule("unrolled")

    def model_fn(p, b):
        return model.forward(p, b, mode="unrolled")["logits"]

    base = model_fn(params, batch)

    g = InterventionGraph()
    t = g.add("tap_get", site="layers.ssm_state", layer=0)
    z = g.add("mul", Ref(t.id), 0.0)
    g.add("tap_set", Ref(z.id), site="layers.ssm_state", layer=0)
    o = g.add("tap_get", site="logits")
    s = g.add("save", Ref(o.id))
    g.mark_saved("out", s)
    _, saves, _ = run_interleaved(
        model_fn, g, schedule, (params, batch), {}, mode="unrolled"
    )
    # zeroing the final chunk state of layer 0 must change downstream logits
    # only through the state path; the full-sequence output path (which uses
    # intra-chunk terms too) may or may not differ — assert finiteness and
    # shape, and that the tap was applied (saved output exists).
    assert saves["out"].shape == base.shape
    assert np.isfinite(np.asarray(saves["out"])).all()
