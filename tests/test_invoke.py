"""Invoke-based tracer API: multi-invoke traces, cross-trace sessions, and
early-stop (paper §3.2, Fig. 3).

Layers under test:
  * tracer level — ``tr.invoke`` sub-contexts lower into ONE merged forward
    (per-invoke getters sliced to rows/true lengths, setters row-confined);
    parity vs solo traces across all four model families;
  * generation — multi-invoke ``lm.generate()`` rides one slot-table decode
    loop with per-invoke ``max_new_tokens``;
  * sessions — forward value flow (a saved proxy from trace k consumed by
    trace k+1), locally and over the wire as one request; edge-case guards;
  * early stop — ``tr.stop()`` truncates execution after the last
    referenced site, locally and server-side;
  * serving — premerged wire form, zero recompiles on repeat requests;
  * discoverability — ``Envoy.__dir__``, ``Tracer.result`` KeyError, and
    ``scan=True`` prefill shape validation for generation traces.

Parity conventions (see tests/test_ragged.py): causal families are held to
bit-exact, encdec to 1e-5 (non-causal encoder softmax reduction order).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batching import merge_invoke_batches, split_invokes
from repro.core.graph import GraphValidationError, InterventionGraph, Ref
from repro.core.interleave import SiteSchedule
from repro.core.serialize import dumps, loads
from repro.core.tracer import TracedModel
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer

FAMILIES = {
    "paper-gpt-small": "transformer",
    "mamba2-1.3b": "ssm",
    "zamba2-2.7b": "hybrid",
    "seamless-m4t-large-v2": "encdec",
}


@pytest.fixture(scope="module", params=sorted(FAMILIES))
def family(request):
    arch = request.param
    cfg = R.get_config(arch, reduced=True)
    model = R.build_model(arch, cfg)
    params = model.init(jax.random.key(0))
    return arch, cfg, model, params


@pytest.fixture(scope="module")
def gpt_lm():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, traced_lm(model, params)


def _tokens(cfg, rows, seq, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, (rows, seq)).astype(np.int32)


def _extras(cfg, rows, seed):
    if cfg.arch_type != "audio":
        return {}
    rng = np.random.default_rng(seed + 1000)
    return {"src_embeds": rng.standard_normal(
        (rows, cfg.n_source_frames, cfg.d_model)).astype(np.float32)}


def _probe_site(cfg):
    return "decoder.output" if cfg.arch_type == "audio" else "layers.output"


def _counting_model(n_layers=3, d=4):
    """Tiny model whose site fires are observable (stop/merge counting)."""
    fired = []
    from repro.core import taps

    ws = jnp.stack(
        [jnp.eye(d, dtype=jnp.float32) * (i + 1) for i in range(n_layers)]
    )

    def model_fn(params, x):
        fired.append("embed")
        h = taps.site("embed", x)
        for i in range(n_layers):
            h = taps.site("layers.input", h, layer=i)
            fired.append(f"layer{i}")  # about to pay for layer i's matmul
            h = h @ params["w"][i]
            h = taps.site("layers.output", h, layer=i)
        fired.append("logits")
        return taps.site("logits", h)

    order = [("embed", None)]
    for i in range(n_layers):
        order += [("layers.input", i), ("layers.output", i)]
    order += [("logits", None)]
    lm = TracedModel(model_fn, {"w": ws},
                     SiteSchedule(order, (), n_layers), name="counting")
    return lm, fired, ws


# ------------------------------------------------------------ tracer level
class TestInvokeTrace:
    def test_two_invokes_one_forward_parity(self):
        lm, fired, ws = _counting_model()
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        with lm.trace() as tr:
            with tr.invoke(x) as i0:
                a = lm.layers[0].output.save("acts")
                o0 = lm.output.save("out")
            with tr.invoke(3 * x) as i1:
                o1 = lm.output.save("out")
        assert fired.count("embed") == 1  # ONE merged forward
        with lm.trace(x):
            r0 = lm.output.save("o")
        with lm.trace(3 * x):
            r1 = lm.output.save("o")
        np.testing.assert_array_equal(np.asarray(o0.value), np.asarray(r0.value))
        np.testing.assert_array_equal(np.asarray(o1.value), np.asarray(r1.value))
        np.testing.assert_array_equal(np.asarray(a.value), np.asarray(x @ ws[0]))
        # per-invoke access mirrors the flat aliases
        np.testing.assert_array_equal(
            np.asarray(i0.result("out")), np.asarray(o0.value))
        np.testing.assert_array_equal(
            np.asarray(i1.result("out")), np.asarray(o1.value))

    def test_setter_confined_to_its_invoke(self):
        lm, _, _ = _counting_model()
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        with lm.trace() as tr:
            with tr.invoke(x):
                lm.layers[0].output = 0.0 * lm.layers[0].output
                z = lm.output.save("out")
            with tr.invoke(x):
                nz = lm.output.save("out")
        with lm.trace(x):
            ref = lm.output.save("o")
        assert np.all(np.asarray(z.value) == 0)
        np.testing.assert_array_equal(np.asarray(nz.value), np.asarray(ref.value))

    def test_duplicate_name_needs_invoke_scope(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with lm.trace() as tr:
            with tr.invoke(x):
                lm.output.save("out")
            with tr.invoke(2 * x):
                lm.output.save("out")
        # qualified names always resolve; the bare duplicate does not
        assert tr.result("i0/out") is not None
        with pytest.raises(KeyError, match="i1/out"):
            tr.result("out")

    def test_result_keyerror_names_available(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with lm.trace(x) as tr:
            lm.output.save("present")
        with pytest.raises(KeyError, match="available: \\['present'\\]"):
            tr.result("absent")

    def test_invoke_api_guards(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with lm.trace(x) as tr:
            tr._deferred = True
            with pytest.raises(RuntimeError, match="multi-invoke"):
                tr.invoke(x)
        with pytest.raises(GraphValidationError, match="invoke"):
            with lm.trace():
                pass  # no invokes declared
        with lm.trace() as tr:
            tr._deferred = True
            with tr.invoke(x):
                with pytest.raises(RuntimeError, match="nested"):
                    with tr.invoke(x):
                        pass

    def test_tap_outside_invoke_rejected(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with pytest.raises(ValueError, match="outside"):
            with lm.trace() as tr:
                tr.invoke(x)  # declared but tapped outside the context
                lm.output.save("out")

    def test_cross_invoke_flow_rejected(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with pytest.raises(ValueError, match="cross-invoke"):
            with lm.trace() as tr:
                with tr.invoke(x):
                    h = lm.layers[0].output
                with tr.invoke(x):
                    lm.layers[1].output = h * 2.0

    def test_shared_constant_replicated(self):
        lm, _, ws = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with lm.trace() as tr:
            scale = tr.constant(np.float32(2.0))  # outside any invoke
            with tr.invoke(x):
                lm.layers[0].output = lm.layers[0].output * scale
                a = lm.output.save("out")
            with tr.invoke(x):
                lm.layers[0].output = lm.layers[0].output * scale
                b = lm.output.save("out")
        np.testing.assert_array_equal(np.asarray(a.value), np.asarray(b.value))

    def test_invoke_free_save_collision_rejected(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with pytest.raises(ValueError, match="ambiguous"):
            with lm.trace() as tr:
                with tr.invoke(x):
                    lm.output.save("x")
                # invoke-free save of the SAME name lands on invoke 0 too
                tr.constant(np.float32(3.0)).save("x")

    def test_envoy_dir_lists_children(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with lm.trace(x) as tr:
            tr._deferred = True
            assert dir(lm.layers[0]) == ["input", "output"]
            root = dir(lm)
        for name in ("embed", "layers", "logits", "output"):
            assert name in root


def test_three_invoke_ragged_parity(family):
    """The acceptance bar: a 3-invoke ragged trace executes as ONE merged
    forward with per-invoke results bit-exact vs three solo traces (causal
    families; encdec 1e-5)."""
    arch, cfg, model, params = family
    lm = traced_lm(model, params)
    site = _probe_site(cfg)
    lengths = (10, 14, 7)
    toks = [_tokens(cfg, 1, s, i) for i, s in enumerate(lengths)]
    extras = [_extras(cfg, 1, i) for i in range(3)]

    calls = {"n": 0}
    orig = model.forward

    def counted(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    model.forward = counted
    try:
        with lm.trace() as tr:
            invs = []
            for t, ex in zip(toks, extras):
                with tr.invoke(t, **ex) as inv:
                    lm_site = lm
                    for part in site.split(".")[:-1]:
                        lm_site = getattr(lm_site, part)
                    getattr(lm_site[1], site.split(".")[-1]).save("acts")
                    lm.output.save("out")
                    invs.append(inv)
        assert calls["n"] == 1, "expected ONE merged forward"
    finally:
        model.forward = orig

    for inv, t, ex in zip(invs, toks, extras):
        with lm.trace(t, **ex):
            lm_site = lm
            for part in site.split(".")[:-1]:
                lm_site = getattr(lm_site, part)
            sa = getattr(lm_site[1], site.split(".")[-1]).save("acts")
            so = lm.output.save("out")
        got_a, got_o = np.asarray(inv.result("acts")), np.asarray(inv.result("out"))
        want_a, want_o = np.asarray(sa.value), np.asarray(so.value)
        assert got_a.shape == want_a.shape  # true solo shapes, not padded
        assert got_o.shape == want_o.shape
        if FAMILIES[arch] == "encdec":
            np.testing.assert_allclose(got_a, want_a, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(got_o, want_o, rtol=1e-5, atol=1e-5)
        else:
            np.testing.assert_array_equal(got_a, want_a)
            np.testing.assert_array_equal(got_o, want_o)


def test_merge_invoke_batches_ragged():
    b0 = {"tokens": np.ones((2, 5), np.int32)}
    b1 = {"tokens": np.ones((1, 8), np.int32)}
    batch, tap_lengths, sizes, real, padded = merge_invoke_batches([b0, b1])
    assert batch["tokens"].shape == (3, 8)
    np.testing.assert_array_equal(batch["lengths"], [5, 5, 8])
    assert tap_lengths == [{"tokens": 5}, {"tokens": 8}]
    assert sizes == [2, 1] and real == 2 * 5 + 8 and padded == 2 * 3


def test_split_invokes_wire_roundtrip():
    g = InterventionGraph()
    g.invoke_default = 0
    t0 = g.add("tap_get", site="logits")
    g.mark_saved("i0/out", g.add("save", Ref(t0.id)))
    g.invoke_default = 1
    t1 = g.add("tap_get", site="logits")
    s1 = g.add("mul", Ref(t1.id), np.float32(2.0))
    g.mark_saved("i1/out", g.add("save", Ref(s1.id)))
    g.invoke_default = None
    g2 = loads(dumps(g))  # invoke coordinate survives the wire
    assert [n.invoke for n in g2.nodes] == [n.invoke for n in g.nodes]
    subs = split_invokes(g2, 2)
    assert len(subs) == 2
    assert list(subs[0].saves) == ["out"] and list(subs[1].saves) == ["out"]
    assert all(n.invoke is None for sub in subs for n in sub.nodes)


# ------------------------------------------------------------- early stop
class TestStop:
    def test_stop_truncates_after_last_referenced_site(self):
        lm, fired, ws = _counting_model()
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        with lm.trace(x) as tr:
            h = lm.layers[0].output.save("h")
            tr.stop()
        # layer 0's matmul ran; layers 1, 2 and logits were never computed
        assert "layer0" in fired and "layer1" not in fired
        assert "logits" not in fired
        np.testing.assert_array_equal(np.asarray(tr.result("h")),
                                      np.asarray(x @ ws[0]))

    def test_stop_with_setter_still_applies(self):
        lm, fired, ws = _counting_model()
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        with lm.trace(x) as tr:
            lm.layers[1].output = lm.layers[1].output * 0.0
            h = lm.layers[1].output.save("h")
            tr.stop()
        assert np.all(np.asarray(tr.result("h")) == 0)
        assert "layer2" not in fired

    def test_stop_with_grad_truncates_and_differentiates(self):
        # stop() + .grad now compose: the perturbation driver
        # differentiates the TRUNCATED forward.  Loss reads layer 1, grad
        # taps layer 0 — layer 2 and the logits head never execute, yet the
        # gradient matches the full-model run (the backward only needs the
        # forward up to the loss).
        lm, fired, ws = _counting_model()
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        with lm.trace(x) as tr:
            g = lm.layers[0].output.grad.save("g")
            loss = (lm.layers[1].output * lm.layers[1].output).sum().save("loss")
            tr.backward(loss)
            tr.stop()
        assert "layer1" in fired and "layer2" not in fired
        assert "logits" not in fired
        h1 = np.asarray(x) @ np.asarray(ws[0]) @ np.asarray(ws[1])
        expect = (2 * h1) @ np.asarray(ws[1]).T  # dL/d(h0) for L = sum(h1^2)
        np.testing.assert_allclose(tr.result("g"), expect, rtol=1e-5)

    def test_stop_in_multi_invoke_trace(self):
        lm, fired, ws = _counting_model()
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        with lm.trace() as tr:
            with tr.invoke(x):
                a = lm.layers[0].output.save("h")
            with tr.invoke(3 * x):
                b = lm.layers[0].output.save("h")
            tr.stop()
        assert "layer1" not in fired
        np.testing.assert_array_equal(np.asarray(a.value), np.asarray(x @ ws[0]))
        np.testing.assert_array_equal(np.asarray(b.value),
                                      np.asarray(3 * x @ ws[0]))


# ---------------------------------------------------------------- sessions
class TestSessionFlow:
    def test_local_cross_trace_value(self):
        lm, _, ws = _counting_model()
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        with lm.session() as sess:
            with sess.trace(x):
                acts = lm.layers[0].output.save("acts")
            with sess.trace(x):
                lm.layers[0].output = acts * 2.0
                out = lm.output.save("out")
        with lm.trace(x):
            lm.layers[0].output = lm.layers[0].output * 2.0
            ref = lm.output.save("out")
        np.testing.assert_allclose(np.asarray(out.value),
                                   np.asarray(ref.value), rtol=1e-6)

    def test_cross_trace_requires_save(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with pytest.raises(GraphValidationError, match="save"):
            with lm.session() as sess:
                with sess.trace(x):
                    acts = lm.layers[0].output  # NOT saved
                with sess.trace(x):
                    lm.layers[0].output = acts * 2.0

    def test_foreign_proxy_outside_session_rejected(self):
        lm, _, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with lm.trace(x):
            saved = lm.output.save("o")
        with pytest.raises(GraphValidationError, match="session"):
            with lm.trace(x) as t2:
                t2._deferred = True
                lm.layers[0].output = saved * 2.0

    def test_nested_sessions_rejected(self):
        lm, _, _ = _counting_model()
        with lm.session():
            with pytest.raises(RuntimeError, match="nested"):
                with lm.session():
                    pass

    def test_remote_session_without_backend_fails_early(self):
        lm, _, _ = _counting_model()
        with pytest.raises(RuntimeError, match="backend"):
            lm.session(remote=True)

    def test_exception_in_deferred_trace_skips_later_traces(self):
        lm, fired, _ = _counting_model()
        x = jnp.ones((1, 4), jnp.float32)
        with pytest.raises(ValueError, match="boom"):
            with lm.session() as sess:
                with sess.trace(x) as t1:
                    t1_out = lm.output.save("out")
                with sess.trace(x):
                    lm.output.save("out")
                    raise ValueError("boom")
        assert fired == []  # nothing executed — including the VALID trace
        with pytest.raises(RuntimeError):
            t1.result("out")


# ----------------------------------------------------- remote / wire level
@pytest.fixture(scope="module")
def served(gpt_lm):
    cfg, model, _ = gpt_lm
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host("gpt", model, params, policy="sequential")
    client = NDIFClient(LoopbackTransport(server.handle), "gpt")
    lm = traced_lm(model, params, backend=client)
    return cfg, model, params, server, client, lm


class TestRemote:
    def test_premerged_trace_roundtrip_and_zero_recompile(self, served):
        cfg, model, params, server, client, lm = served
        engine = server.engines["gpt"]
        ta, tb = _tokens(cfg, 1, 6, 0), _tokens(cfg, 1, 9, 1)

        def run():
            with lm.trace(remote=True) as tr:
                with tr.invoke(ta):
                    a = lm.layers[1].output.save("acts")
                with tr.invoke(tb):
                    b = lm.output.save("out")
            return np.asarray(a.value), np.asarray(b.value)

        a1, b1 = run()
        assert a1.shape[1] == 6 and b1.shape[1] == 9  # true solo shapes
        c0 = engine.stats.compiles
        a2, b2 = run()
        assert engine.stats.compiles == c0, "2nd identical multi-invoke " \
            "trace must not compile"
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(b1, b2)

    def test_remote_stop_matches_local(self, served):
        cfg, model, params, server, client, lm = served
        t = _tokens(cfg, 1, 8, 2)
        with lm.trace(t, remote=True) as tr:
            lm.layers[0].output.save("h")
            tr.stop()
        lm_local = traced_lm(model, params)
        with lm_local.trace(t):
            ref = lm_local.layers[0].output.save("h")
        np.testing.assert_allclose(
            np.asarray(tr.result("h")), np.asarray(ref.value),
            rtol=1e-5, atol=1e-5)

    def test_remote_session_cross_trace(self, served):
        cfg, model, params, server, client, lm = served
        t = _tokens(cfg, 1, 8, 3)
        with lm.session(remote=True) as sess:
            with sess.trace(t):
                acts = lm.layers[1].output.save("acts")
            with sess.trace(t):
                lm.layers[1].output = acts * 0.5
                out = lm.output.save("out")
        with lm.trace(t, remote=True) as ref:
            lm.layers[1].output = lm.layers[1].output * 0.5
            ref_out = lm.output.save("out")
        np.testing.assert_allclose(np.asarray(out.value),
                                   np.asarray(ref_out.value),
                                   rtol=1e-5, atol=1e-5)

    def test_remote_session_multi_invoke_producer(self, served):
        """Cross refs from a multi-invoke producer: both the qualified
        (``i{k}/name`` -> ``r{k}/name``) and the invoke-free (-> ``r0/``)
        save forms must resolve server-side."""
        cfg, model, params, server, client, lm = served
        ta, tb = _tokens(cfg, 1, 8, 11), _tokens(cfg, 1, 8, 12)
        with lm.session(remote=True) as sess:
            with sess.trace() as t1:
                with t1.invoke(ta):
                    acts = lm.layers[1].output.save("acts")
                free = t1.constant(np.float32(0.5)).save("scale")
            with sess.trace(tb):
                lm.layers[1].output = lm.layers[1].output * free
                lm.layers[1].output[:, -1] = acts[:, -1]
                out = lm.output.save("out")
        with lm.trace(ta, remote=True):
            ref_acts = lm.layers[1].output.save("acts")
        with lm.trace(tb, remote=True) as ref:
            lm.layers[1].output = lm.layers[1].output * 0.5
            lm.layers[1].output[:, -1] = ref.constant(
                np.asarray(ref_acts.value)[:, -1])
            ref_out = lm.output.save("out")
        np.testing.assert_allclose(np.asarray(out.value),
                                   np.asarray(ref_out.value),
                                   rtol=1e-5, atol=1e-5)

    def test_scan_session_trace_with_cross_input(self, served):
        """scan=True on a deferred trace consuming an earlier save:
        validation waits until the session binds the value (finding from
        review: it used to KeyError at trace exit)."""
        cfg, model, params, server, client, lm = served
        lm_local = traced_lm(model, params)
        t = _tokens(cfg, 1, 8, 13)
        with lm_local.session() as sess:
            with sess.trace(t, scan=True):
                acts = lm_local.layers[1].output.save("acts")
            with sess.trace(t, scan=True):
                lm_local.layers[1].output = acts * 0.5
                out = lm_local.output.save("out")
        with lm_local.trace(t):
            lm_local.layers[1].output = lm_local.layers[1].output * 0.5
            ref = lm_local.output.save("out")
        np.testing.assert_allclose(np.asarray(out.value),
                                   np.asarray(ref.value), rtol=1e-6)


# --------------------------------------------------------------- generation
class TestGenerateInvokes:
    def test_multi_invoke_generate_parity(self, gpt_lm):
        cfg, model, lm = gpt_lm
        ta, tb = _tokens(cfg, 1, 6, 0), _tokens(cfg, 2, 9, 1)
        with lm.generate() as tr:
            with tr.invoke(ta, max_new_tokens=3) as ia:
                for _ in tr.steps():
                    lm.logits.save("logits")
            with tr.invoke(tb, max_new_tokens=6) as ib:
                lm.layers[1].mlp.output.save("acts")  # step 0 tap
        assert ia.output_tokens.shape == (1, 3)
        assert ib.output_tokens.shape == (2, 6)  # retires at ITS OWN N
        with lm.generate(ta, max_new_tokens=3) as ga:
            for _ in ga.steps():
                lm.logits.save("logits")
        with lm.generate(tb, max_new_tokens=6) as gb:
            lm.layers[1].mlp.output.save("acts")
        np.testing.assert_array_equal(ia.output_tokens, ga.output_tokens)
        np.testing.assert_array_equal(ib.output_tokens, gb.output_tokens)
        np.testing.assert_array_equal(
            np.asarray(ia.result("logits")), np.asarray(ga.result("logits")))
        np.testing.assert_array_equal(
            np.asarray(ib.result("acts")), np.asarray(gb.result("acts")))

    def test_multi_invoke_generate_steering(self, gpt_lm):
        cfg, model, lm = gpt_lm
        ta, tb = _tokens(cfg, 1, 6, 2), _tokens(cfg, 1, 6, 3)
        bias = np.zeros((1, 1, cfg.vocab_size), np.float32)
        bias[..., 7] = 1e9  # steer the logits site directly (argmax-safe)
        with lm.generate() as tr:
            with tr.invoke(ta, max_new_tokens=4) as ia:
                with tr.all_steps():
                    lm.logits += bias
            with tr.invoke(tb, max_new_tokens=4) as ib:
                pass
        assert np.all(ia.output_tokens == 7)  # steered invoke
        with lm.generate(tb, max_new_tokens=4) as gb:
            pass
        np.testing.assert_array_equal(  # co-resident invoke untouched
            ib.output_tokens, gb.output_tokens)

    def test_remote_generate_invokes(self, served):
        cfg, model, params, server, client, lm = served
        engine = server.engines["gpt"]
        ta, tb = _tokens(cfg, 1, 6, 4), _tokens(cfg, 1, 9, 5)

        def run():
            with lm.generate(remote=True) as tr:
                with tr.invoke(ta, max_new_tokens=3) as ia:
                    for _ in tr.steps():
                        lm.logits.save("logits")
                with tr.invoke(tb, max_new_tokens=5) as ib:
                    pass
            return ia, ib

        ia, ib = run()
        lm_local = traced_lm(model, params)
        with lm_local.generate(ta, max_new_tokens=3) as ga:
            for _ in ga.steps():
                lm_local.logits.save("logits")
        with lm_local.generate(tb, max_new_tokens=5) as gb:
            pass
        np.testing.assert_array_equal(ia.output_tokens, ga.output_tokens)
        np.testing.assert_array_equal(ib.output_tokens, gb.output_tokens)
        assert np.asarray(ia.result("logits")).shape == (1, 3, cfg.vocab_size)
        c0 = engine.stats.compiles
        run()
        assert engine.stats.compiles == c0, "2nd identical multi-invoke " \
            "generate must not compile"

    def test_remote_generate_invokes_continuous_policy(self, gpt_lm):
        cfg, model, _ = gpt_lm
        params = model.init(jax.random.key(0))
        server = NDIFServer()
        server.host("gpt", model, params, policy="continuous",
                    num_slots=4, slot_max_len=48)
        client = NDIFClient(LoopbackTransport(server.handle), "gpt")
        lm = traced_lm(model, params, backend=client)
        ta, tb = _tokens(cfg, 1, 6, 6), _tokens(cfg, 1, 7, 7)
        with lm.generate(remote=True) as tr:
            with tr.invoke(ta, max_new_tokens=3) as ia:
                pass
            with tr.invoke(tb, max_new_tokens=5) as ib:
                pass
        stats = server.engines["gpt"].stats
        assert stats.admissions == 2  # both invokes rode the slot loop
        lm_local = traced_lm(model, params)
        with lm_local.generate(ta, max_new_tokens=3) as ga:
            pass
        with lm_local.generate(tb, max_new_tokens=5) as gb:
            pass
        np.testing.assert_array_equal(ia.output_tokens, ga.output_tokens)
        np.testing.assert_array_equal(ib.output_tokens, gb.output_tokens)

    def test_generate_scan_validation(self, gpt_lm):
        cfg, model, lm = gpt_lm
        t = _tokens(cfg, 1, 8, 8)

        # good graph: prefill tap validates and the trace then executes
        with lm.generate(t, max_new_tokens=2, scan=True) as tr:
            with tr.prefill():
                lm.layers[1].output.save("pre")
            lm.logits.save("logits")
        assert np.asarray(tr.result("pre")).shape == (1, 7, cfg.d_model)

        # bad graph: shape error in a prefill-step op is caught by
        # eval_shape (abstract values only — no FLOPs) and the trace never
        # executes
        with pytest.raises(TypeError):
            with lm.generate(t, max_new_tokens=2, scan=True) as tr:
                with tr.prefill():
                    bad = lm.layers[1].output.reshape(7)
                    bad.save("bad")
        assert tr.output_tokens is None and tr._results is None

    def test_generate_scan_multi_invoke(self, gpt_lm):
        cfg, model, lm = gpt_lm
        ta, tb = _tokens(cfg, 1, 6, 9), _tokens(cfg, 1, 9, 10)
        with lm.generate(scan=True) as tr:
            with tr.invoke(ta, max_new_tokens=2) as ia:
                with tr.prefill():
                    lm.layers[1].output.save("pre")
            with tr.invoke(tb, max_new_tokens=3) as ib:
                lm.logits.save("logits")
        assert np.asarray(ia.result("pre")).shape == (1, 5, cfg.d_model)
        assert np.asarray(ib.result("logits")).shape[1] == 1
