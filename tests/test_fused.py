"""Fused decode: the whole decode loop compiled into ONE lax.scan program.

Layers under test:
  * ``steps_uniform`` — which generation graphs are step-uniform (the
    fused-eligible class: uninstrumented, ``all_steps()``-only, identical
    per-step site/op sets; per-step constant VALUES may differ);
  * fused == eager parity for solo generate, multi-invoke generate, and a
    continuous-loop schedule with admissions between fused segments, across
    all four model families;
  * segment splitting — a trace instrumented at SOME steps fuses the
    uniform stretches; single non-uniform steps run as length-1 windows of
    the same compiled machinery (window splits are bit-identical);
  * engine caching — a repeat fused request performs zero new compiles;
  * EngineStats ``fused_segments`` / ``fused_steps`` / ``eager_steps``,
    through the stats endpoint and ``client.stats()``.

Parity bars (repo conventions): greedy tokens are compared EXACTLY for all
four families.  Saves are bit-exact when both sides run compiled (the
uninstrumented path); instrumented comparisons pit the compiled scan
against the UNJITTED eager interleaver, which rounds at the ~2e-6 level on
CPU, so those use the repo's standard 1e-5 cross-strategy tolerance
(encdec always 1e-5).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.generation import (
    DecodeLoop,
    make_fused_step,
    run_generation,
    run_generation_invokes,
    steps_uniform,
)
from repro.core.graph import (
    ALL_STEPS,
    PREFILL_STEP,
    GraphValidationError,
    InterventionGraph,
    Ref,
)
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request

FAMILIES = {
    "paper-gpt-small": "transformer",
    "mamba2-1.3b": "ssm",
    "zamba2-2.7b": "hybrid",
    "seamless-m4t-large-v2": "encdec",
}


@pytest.fixture(scope="module", params=sorted(FAMILIES))
def family(request):
    arch = request.param
    cfg = R.get_config(arch, reduced=True)
    model = R.build_model(arch, cfg)
    params = model.init(jax.random.key(0))
    return arch, cfg, model, params


def _batch(cfg, rows, seq, seed):
    rng = np.random.default_rng(seed)
    batch = {"tokens": rng.integers(1, cfg.vocab_size, (rows, seq)).astype(np.int32)}
    if cfg.arch_type == "audio":
        batch["src_embeds"] = rng.standard_normal(
            (rows, cfg.n_source_frames, cfg.d_model)).astype(np.float32)
    return batch


def _site(arch):
    return {
        "ssm": "layers.mixer.output",
        "hybrid": "layers.mixer.output",
        "encdec": "decoder.mlp.output",
    }.get(FAMILIES[arch], "layers.mlp.output")


def _steer_graph(cfg, arch, n_steps, *, save=True):
    """all_steps() setter + per-step logits saves — step-uniform."""
    g = InterventionGraph()
    t = g.add("tap_get", site=_site(arch), layer=0, step=ALL_STEPS)
    c = g.add("constant", np.float32(5.0))
    u = g.add("add", Ref(t.id), Ref(c.id))
    g.add("tap_set", Ref(u.id), site=_site(arch), layer=0, step=ALL_STEPS)
    if save:
        for s in range(n_steps):
            tt = g.add("tap_get", site="logits", step=s)
            g.mark_saved(f"lg@step{s}", g.add("save", Ref(tt.id)))
    return g


def _assert_match(arch, got, want, *, exact):
    exact = exact and FAMILIES[arch] != "encdec"
    np.testing.assert_array_equal(np.asarray(got.tokens),
                                  np.asarray(want.tokens))
    assert sorted(got.saves) == sorted(want.saves)
    for k in want.saves:
        if exact:
            np.testing.assert_array_equal(np.asarray(got.saves[k]),
                                          np.asarray(want.saves[k]))
        else:
            np.testing.assert_allclose(np.asarray(got.saves[k]),
                                       np.asarray(want.saves[k]),
                                       rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- steps_uniform
def test_steps_uniform_classes():
    assert steps_uniform(InterventionGraph(), 4)  # uninstrumented

    g = InterventionGraph()  # all_steps-only
    t = g.add("tap_get", site="logits", step=ALL_STEPS)
    g.add("tap_set", Ref(t.id), site="logits", step=ALL_STEPS)
    assert steps_uniform(g, 4)

    g = InterventionGraph()  # identical per-step saves
    for s in range(3):
        t = g.add("tap_get", site="logits", step=s)
        g.mark_saved(f"lg@step{s}", g.add("save", Ref(t.id)))
    assert steps_uniform(g, 3)

    g = InterventionGraph()  # prefill-only instrumentation is uniform
    t = g.add("tap_get", site="embed", step=PREFILL_STEP)
    g.mark_saved("emb", g.add("save", Ref(t.id)))
    assert steps_uniform(g, 3)

    g = InterventionGraph()  # one instrumented step out of N
    t = g.add("tap_get", site="logits", step=1)
    g.mark_saved("lg", g.add("save", Ref(t.id)))
    assert not steps_uniform(g, 3)

    g = InterventionGraph()  # differing sites per step
    t0 = g.add("tap_get", site="logits", step=0)
    g.mark_saved("a", g.add("save", Ref(t0.id)))
    t1 = g.add("tap_get", site="embed", step=1)
    g.mark_saved("b", g.add("save", Ref(t1.id)))
    assert not steps_uniform(g, 2)

    g = InterventionGraph()  # cross-step env flow
    t = g.add("tap_get", site="logits", step=0)
    g.add("tap_set", Ref(t.id), site="logits", step=1)
    assert not steps_uniform(g, 2)

    g = InterventionGraph()  # logs lower to jax.debug.callback — fusable
    for s in range(2):
        t = g.add("tap_get", site="logits", step=s)
        g.add("log", Ref(t.id), step=s)
    assert steps_uniform(g, 2)


def test_steps_uniform_allows_varying_constants():
    """Identical structure with different per-step constant VALUES is still
    uniform: values thread through the scan as stacked inputs."""
    g = InterventionGraph()
    for s in range(3):
        t = g.add("tap_get", site="logits", step=s)
        c = g.add("constant", np.float32(s + 1))
        u = g.add("add", Ref(t.id), Ref(c.id))
        g.add("tap_set", Ref(u.id), site="logits", step=s)
    assert steps_uniform(g, 3)


# ------------------------------------------------------------- solo parity
def test_solo_generate_fused_matches_eager(family):
    """Uninstrumented: fused scan vs compiled eager stepping, BIT-exact
    tokens and logits for every family."""
    arch, cfg, model, params = family
    engine = InferenceEngine(model, params, mode="unrolled")
    batch = _batch(cfg, 2, 6, 0)
    got = engine.generate_interleaved(InterventionGraph(), dict(batch), 5,
                                      fused=True)
    want = engine.generate_interleaved(InterventionGraph(), dict(batch), 5,
                                       fused=False)
    np.testing.assert_array_equal(np.asarray(got.tokens),
                                  np.asarray(want.tokens))
    np.testing.assert_array_equal(np.asarray(got.logits),
                                  np.asarray(want.logits))
    assert engine.stats.fused_segments >= 1
    assert engine.stats.fused_steps == 5
    assert engine.stats.eager_steps == 5


def test_solo_generate_steered_fused_matches_eager(family):
    """all_steps() steering + per-step stacked saves: tokens exact, saves
    at the cross-strategy tolerance (the eager side runs unjitted)."""
    arch, cfg, model, params = family
    N = 4
    batch = _batch(cfg, 2, 6, 1)
    tokens = jnp.asarray(batch.pop("tokens"))
    g = _steer_graph(cfg, arch, N)
    got = run_generation(model, params, g, tokens, N, mode="unrolled",
                         extras=batch, fused=True)
    want = run_generation(model, params, g, tokens, N, mode="unrolled",
                          extras=batch, fused=False)
    _assert_match(arch, got, want, exact=False)
    assert sorted(got.saves) == [f"lg@step{s}" for s in range(N)]


def test_solo_generate_prefill_tap_rides_fused(family):
    """Prefill instrumentation does not break decode fusion: the prompt
    forward runs interleaved, the decode loop still fuses."""
    arch, cfg, model, params = family
    g = InterventionGraph()
    t = g.add("tap_get", site="embed", step=PREFILL_STEP)
    g.mark_saved("emb", g.add("save", Ref(t.id)))
    batch = _batch(cfg, 1, 6, 2)
    tokens = jnp.asarray(batch.pop("tokens"))
    engine = InferenceEngine(model, params, mode="unrolled")
    got = run_generation(model, params, g, tokens, 4, mode="unrolled",
                         extras=dict(batch), fused=True,
                         fused_fn=engine._fused_factory, stats=engine.stats)
    want = run_generation(model, params, g, tokens, 4, mode="unrolled",
                          extras=dict(batch), fused=False)
    _assert_match(arch, got, want, exact=False)
    assert engine.stats.fused_steps == 4


def test_scan_mode_fused_matches_eager():
    """mode="scan" nests the model's layer scan inside the fused step scan."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, 2, 6, 3)
    tokens = jnp.asarray(batch.pop("tokens"))
    g = _steer_graph(cfg, "paper-gpt-small", 4)
    got = run_generation(model, params, g, tokens, 4, mode="scan",
                         fused=True)
    want = run_generation(model, params, g, tokens, 4, mode="scan",
                          fused=False)
    _assert_match("paper-gpt-small", got, want, exact=False)


def test_partial_instrumentation_fuses_uniform_segments():
    """Steering only steps 2..3 of 6: the plain stretches and the steered
    stretch each fuse as their own segment; results match eager exactly on
    tokens."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(_batch(cfg, 2, 6, 4)["tokens"])

    def mk():
        g = InterventionGraph()
        for s in (2, 3):
            t = g.add("tap_get", site="layers.mlp.output", layer=1, step=s)
            c = g.add("constant", np.float32(25.0))
            u = g.add("add", Ref(t.id), Ref(c.id))
            g.add("tap_set", Ref(u.id), site="layers.mlp.output", layer=1,
                  step=s)
        return g

    assert not steps_uniform(mk(), 6)
    engine = InferenceEngine(model, params, mode="unrolled")
    got = engine.generate_interleaved(mk(), {"tokens": toks}, 6, fused=True)
    want = engine.generate_interleaved(mk(), {"tokens": toks}, 6,
                                       fused=False)
    np.testing.assert_array_equal(np.asarray(got.tokens),
                                  np.asarray(want.tokens))
    # 0..1 fused, 2..3 fused (instrumented), 4..5 fused
    assert engine.stats.fused_segments == 3
    assert engine.stats.fused_steps == 6


def test_varying_per_step_constants_fuse_and_match():
    """Same structure, different constant values per step: one scan with
    the values stacked as xs, numerically matching the eager loop."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(_batch(cfg, 1, 6, 5)["tokens"])
    N = 4

    forced = [int(i) for i in
              np.random.default_rng(9).integers(0, cfg.vocab_size, N)]

    def mk():
        g = InterventionGraph()
        for s in range(N):
            t = g.add("tap_get", site="logits", step=s)
            bias = np.zeros((cfg.vocab_size,), np.float32)
            bias[forced[s]] = 1e9
            c = g.add("constant", bias)
            u = g.add("add", Ref(t.id), Ref(c.id))
            g.add("tap_set", Ref(u.id), site="logits", step=s)
            tt = g.add("tap_get", site="logits", step=s)
            g.mark_saved(f"lg@step{s}", g.add("save", Ref(tt.id)))
        return g

    assert steps_uniform(mk(), N)
    engine = InferenceEngine(model, params, mode="unrolled")
    got = engine.generate_interleaved(mk(), {"tokens": toks}, N, fused=True)
    want = engine.generate_interleaved(mk(), {"tokens": toks}, N,
                                       fused=False)
    assert engine.stats.fused_segments == 1
    _assert_match("paper-gpt-small", got, want, exact=False)
    # the per-step steering really applied: each step decoded ITS forced id
    np.testing.assert_array_equal(np.asarray(got.tokens)[0], forced)


# ----------------------------------------------------------- invoke parity
def test_multi_invoke_generate_fused_matches_eager(family):
    """Multi-invoke generation (ragged prompts, per-invoke N) through one
    slot loop: fused vs eager, per-invoke results compared."""
    arch, cfg, model, params = family
    items = [
        (_steer_graph(cfg, arch, 3), _batch(cfg, 1, 6, 10), 3),
        (InterventionGraph(), _batch(cfg, 1, 8, 11), 5),
    ]

    def run(fused):
        return run_generation_invokes(
            model, params,
            [(g, dict(b), n) for g, b, n in items],
            mode="unrolled", fused=fused,
        )

    got, want = run(True), run(False)
    for g_res, w_res in zip(got, want):
        _assert_match(arch, g_res, w_res, exact=False)


def test_multi_invoke_tracer_marks_uniform_and_matches_solo():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    lm = traced_lm(model, params)
    ta = _batch(cfg, 1, 6, 12)["tokens"]
    tb = _batch(cfg, 1, 9, 13)["tokens"]
    with lm.generate() as tr:
        with tr.invoke(ta, max_new_tokens=4):
            for _ in tr.steps():
                lm.logits.save("lg")
        with tr.invoke(tb, max_new_tokens=2) as ib:
            with tr.step(0):
                lm.layers[1].mlp.output += 25.0
    assert tr.steps_uniform == [True, False]
    # per-invoke parity vs solo eager generates
    with lm.generate(ta, max_new_tokens=4) as solo_a:
        for _ in solo_a.steps():
            lm.logits.save("lg")
    np.testing.assert_array_equal(tr.invokes[0].output_tokens,
                                  solo_a.output_tokens)
    with lm.generate(tb, max_new_tokens=2) as solo_b:
        with solo_b.step(0):
            lm.layers[1].mlp.output += 25.0
    np.testing.assert_array_equal(ib.output_tokens, solo_b.output_tokens)


def test_solo_tracer_marks_uniform():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    lm = traced_lm(model, params)
    toks = _batch(cfg, 1, 6, 14)["tokens"]
    with lm.generate(toks, max_new_tokens=3) as tr:
        with tr.all_steps():
            lm.layers[1].mlp.output += 10.0
    assert tr.steps_uniform is True
    with lm.generate(toks, max_new_tokens=3) as tr2:
        with tr2.step(1):
            lm.layers[1].mlp.output += 10.0
    assert tr2.steps_uniform is False


# ------------------------------------------------------- continuous parity
def test_continuous_loop_admissions_between_fused_segments(family):
    """Admissions land between fused segments; every request still matches
    its solo run exactly (tokens) / bit-exact saves for causal families."""
    arch, cfg, model, params = family
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(4, 32)
    ga = _steer_graph(cfg, arch, 6, save=True)
    sa = loop.admit(ga, _batch(cfg, 1, 7, 20), 6, request_id="a", pad_to=10)
    loop.step_fused(2)          # fused segment of 2, then an admission
    sb = loop.admit(InterventionGraph(), _batch(cfg, 2, 5, 21), 4,
                    request_id="b", pad_to=10)
    loop.run_to_completion()
    assert loop.fused_steps >= 4
    assert loop.fused_segments >= 2

    def solo(graph, batch, n):
        l2 = engine.start_decode_loop(4, 32)
        sr = l2.admit(graph, dict(batch), n, pad_to=10)
        l2.run_to_completion()
        return sr.result()

    _assert_match(arch, sa.result(),
                  solo(_steer_graph(cfg, arch, 6), _batch(cfg, 1, 7, 20), 6),
                  exact=True)
    _assert_match(arch, sb.result(),
                  solo(InterventionGraph(), _batch(cfg, 2, 5, 21), 4),
                  exact=True)


def test_continuous_scheduler_drain_uses_fused_segments():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=7,
                              num_slots=4, slot_max_len=32)
    tickets = [
        sched.submit(Request(graph=InterventionGraph(),
                             batch=_batch(cfg, 1, 6 + i, 30 + i),
                             max_new_tokens=3 + i))
        for i in range(5)
    ]
    sched.drain()
    assert all(t.error is None for t in tickets), [t.error for t in tickets]
    assert engine.stats.fused_steps > 0
    # parity vs a sequential engine
    solo = InferenceEngine(model, params, mode="unrolled")
    for i, t in enumerate(tickets):
        res = solo.generate_interleaved(
            InterventionGraph(), _batch(cfg, 1, 6 + i, 30 + i), 3 + i)
        np.testing.assert_array_equal(t.result["tokens"],
                                      np.asarray(res.tokens))


# ----------------------------------------------------------- engine caching
def test_repeat_fused_request_zero_new_compiles():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    g = _steer_graph(cfg, "paper-gpt-small", 4)
    batch = _batch(cfg, 2, 6, 40)
    engine.generate_interleaved(g, dict(batch), 4)
    c0 = engine.stats.compiles
    assert c0 > 0
    res = engine.generate_interleaved(
        _steer_graph(cfg, "paper-gpt-small", 4), dict(batch), 4)
    assert engine.stats.compiles == c0, \
        "2nd identically-shaped fused request must not retrace"
    assert res.tokens.shape == (2, 4)
    # multi-invoke repeat: same property through generate_invokes
    items = [
        (_steer_graph(cfg, "paper-gpt-small", 3), _batch(cfg, 1, 6, 41), 3),
        (InterventionGraph(), _batch(cfg, 1, 8, 42), 3),
    ]
    engine.generate_invokes([(g, dict(b), n) for g, b, n in items])
    c1 = engine.stats.compiles
    engine.generate_invokes([
        (_steer_graph(cfg, "paper-gpt-small", 3), dict(items[0][1]), 3),
        (InterventionGraph(), dict(items[1][1]), 3),
    ])
    assert engine.stats.compiles == c1


def test_fused_stats_reach_the_wire():
    from repro.serving import LoopbackTransport, NDIFClient, NDIFServer

    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host("gpt", model, params)
    client = NDIFClient(LoopbackTransport(server.handle), "gpt")
    toks = _batch(cfg, 1, 6, 50)["tokens"]
    client.generate(toks, max_new_tokens=4)
    stats = client.stats()
    assert stats["fused_segments"] >= 1
    assert stats["fused_steps"] >= 4
    assert "eager_steps" in stats


# --------------------------------------------------------------- edge cases
def test_single_step_generation_fuses_length_one():
    """N == 1 runs as a length-1 window of the SAME compiled scan body —
    single steps and multi-step windows share one execution strategy, so a
    request's numerics never depend on how the loop was windowed."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    res = engine.generate_interleaved(
        InterventionGraph(), _batch(cfg, 2, 6, 60), 1)
    assert res.tokens.shape == (2, 1)
    assert engine.stats.fused_segments == 1
    assert engine.stats.fused_steps == 1
    assert engine.stats.eager_steps == 0


def test_window_splits_are_bit_identical():
    """One window of 4 == two windows of 2 == four single steps, BIT-exact:
    the invariant that keeps slot-loop results independent of co-tenancy
    (admissions change windowing, not numerics)."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")

    def mk():
        g = InterventionGraph()
        for s in range(4):
            t = g.add("tap_get", site="layers.output", layer=1, step=s)
            g.mark_saved(f"h@step{s}", g.add("save", Ref(t.id)))
        return g

    def run(splits):
        loop = engine.start_decode_loop(1, 16)
        sr = loop.admit(mk(), _batch(cfg, 1, 6, 70), 4)
        for k in splits:
            loop.step_fused(k)
        assert not loop.resident
        return sr

    a, b, c = run([4]), run([2, 2]), run([1, 1, 1, 1])
    for other in (b, c):
        np.testing.assert_array_equal(np.asarray(a.result().tokens),
                                      np.asarray(other.result().tokens))
        for key in a.saves:
            np.testing.assert_array_equal(np.asarray(a.saves[key]),
                                          np.asarray(other.saves[key]))


def test_single_token_prompt_fuses():
    """S == 1 (empty-cache init) decodes entirely inside one fused scan."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    batch = _batch(cfg, 2, 1, 61)
    got = engine.generate_interleaved(InterventionGraph(), dict(batch), 4,
                                      fused=True)
    want = engine.generate_interleaved(InterventionGraph(), dict(batch), 4,
                                       fused=False)
    np.testing.assert_array_equal(np.asarray(got.tokens),
                                  np.asarray(want.tokens))
    assert engine.stats.fused_segments == 1


# ------------------------------------------------- compiled eager islands
def _log_graph(n_steps, *, save=False):
    """Per-step logits log (+ optional save) — step-uniform."""
    g = InterventionGraph()
    for s in range(n_steps):
        t = g.add("tap_get", site="logits", step=s)
        m = g.add("jnp.mean", Ref(t.id), step=s)
        g.add("log", Ref(m.id), step=s)
        if save:
            g.mark_saved(f"lg@step{s}", g.add("save", Ref(t.id)))
    return g


def test_log_generation_fuses_with_zero_eager_steps():
    """Per-step logs ride the compiled scan (jax.debug.callback) — no
    eager fallback, values matching the eager interleaver's."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    N = 4
    loop = engine.start_decode_loop(2, 16)
    sr = loop.admit(_log_graph(N), _batch(cfg, 2, 6, 80), N)
    loop.run_to_completion()
    assert loop.eager_steps == 0
    assert loop.islands_compiled >= 1
    got = sr.result()
    assert len(got.logs) == N

    want = run_generation(model, params, _log_graph(N),
                          jnp.asarray(_batch(cfg, 2, 6, 80)["tokens"]), N,
                          mode="unrolled", fused=False)
    assert len(want.logs) == N
    for (_, a), (_, b) in zip(got.logs, want.logs):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got.tokens),
                                  np.asarray(want.tokens))


def test_grad_generation_fused_matches_eager():
    """.grad at a decode step compiles (the perturbation driver runs
    inside the scan body) and matches the eager interleaver."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))

    def mk():
        g = InterventionGraph()
        gr = g.add("grad_get", site="layers.mlp.output", layer=1, step=1)
        g.mark_saved("g", g.add("save", Ref(gr.id)))
        t = g.add("tap_get", site="logits", step=1)
        sq = g.add("mul", Ref(t.id), Ref(t.id), step=1)
        loss = g.add("jnp.sum", Ref(sq.id), step=1)
        g.backward_loss = loss.id
        return g

    toks = jnp.asarray(_batch(cfg, 2, 6, 81)["tokens"])
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(2, 16)
    sr = loop.admit(mk(), {"tokens": toks}, 3)
    loop.run_to_completion()
    assert loop.eager_steps == 0
    assert loop.islands_compiled >= 1
    got = sr.result()
    want = run_generation(model, params, mk(), toks, 3,
                          mode="unrolled", fused=False)
    np.testing.assert_array_equal(np.asarray(got.tokens),
                                  np.asarray(want.tokens))
    np.testing.assert_allclose(np.asarray(got.saves["g"]),
                               np.asarray(want.saves["g"]),
                               rtol=1e-4, atol=1e-5)
    assert np.any(np.asarray(got.saves["g"]) != 0.0)


def test_cotenant_log_isolation_compiled():
    """A log-carrying request sharing the slot table with a clean request,
    entirely on the compiled path: the clean tenant's tokens and saves are
    BIT-exact vs its solo run, every log entry is attributed to its owner
    (the clean request sees none), and no step ran eagerly."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    N = 4

    def clean_graph():
        g = InterventionGraph()
        for s in range(N):
            t = g.add("tap_get", site="layers.output", layer=1, step=s)
            g.mark_saved(f"h@step{s}", g.add("save", Ref(t.id)))
        return g

    loop = engine.start_decode_loop(2, 16)
    sr_log = loop.admit(_log_graph(N), _batch(cfg, 1, 6, 82), N,
                        request_id="logger")
    sr_clean = loop.admit(clean_graph(), _batch(cfg, 1, 6, 83), N,
                          request_id="clean")
    loop.run_to_completion()
    assert loop.eager_steps == 0, "co-tenant logs must not force eager steps"

    assert len(sr_log.result().logs) == N
    assert sr_clean.result().logs == []

    solo = engine.start_decode_loop(2, 16)
    sr_solo = solo.admit(clean_graph(), _batch(cfg, 1, 6, 83), N)
    solo.run_to_completion()
    np.testing.assert_array_equal(np.asarray(sr_clean.result().tokens),
                                  np.asarray(sr_solo.result().tokens))
    for k in sr_solo.saves:
        np.testing.assert_array_equal(np.asarray(sr_clean.saves[k]),
                                      np.asarray(sr_solo.saves[k]))


def test_fused_failure_falls_back_to_eager_isolation():
    """A graph whose user op only fails at run time must not wedge the
    loop: the fused attempt fails, the eager path isolates and evicts the
    offender, co-tenants finish."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(4, 32)

    bad = InterventionGraph()
    t = bad.add("tap_get", site="logits", step=ALL_STEPS)
    c = bad.add("constant", np.ones((3, 7, 11), np.float32))  # bad broadcast
    u = bad.add("add", Ref(t.id), Ref(c.id))
    bad.add("tap_set", Ref(u.id), site="logits", step=ALL_STEPS)

    sr_ok = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 62), 3,
                       request_id="ok")
    sr_bad = loop.admit(bad, _batch(cfg, 1, 6, 63), 3, request_id="bad")
    loop.run_to_completion()
    assert sr_bad.error is not None
    assert sr_ok.error is None
    want = engine.generate_interleaved(
        InterventionGraph(), _batch(cfg, 1, 6, 62), 3, fused=False)
    np.testing.assert_array_equal(np.asarray(sr_ok.result().tokens),
                                  np.asarray(want.tokens))
