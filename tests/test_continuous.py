"""Continuous batching: slot-table decode loop with in-flight admission.

Layers under test:
  * DecodeLoop — admit/step/retire lifecycle, slot reuse, bit-exact
    per-request isolation under interleaved admission schedules (a request's
    saves/tokens must not depend on what was admitted or retired around it);
  * model level — ``cache_write_rows`` / ``cache_clear_rows`` round-trips for
    all four families (exercised through the loop);
  * scheduler level — ``policy="continuous"`` admission (FIFO within bucket,
    all-slots-busy queueing, S == 1 empty-cache admission, solo fallbacks),
    length-aware ``max_batch_cells`` sizing, per-request response times;
  * serving level — ``GenerateTracer(remote=True)`` roundtrip, slot stats.

Parity bars: interleaved-vs-solo THROUGH THE LOOP is bit-exact for causal
families (identical shapes at every stage: prefill batch = the request's own
rows, decode batch = num_slots either way) and 1e-5 for encdec (non-causal
encoder softmax).  Tokens vs the plain solo engine are exact (greedy argmax
is robust to batch-size GEMM tiling noise, baselined in test_ragged).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.generation import DecodeLoop
from repro.core.graph import (
    GraphValidationError,
    InterventionGraph,
    PREFILL_STEP,
    Ref,
)
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving import LoopbackTransport, NDIFClient, NDIFServer
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import (
    CoTenantScheduler,
    Request,
    _admit_key,
    _bucket_ceiling,
)

FAMILIES = {
    "paper-gpt-small": "transformer",
    "mamba2-1.3b": "ssm",
    "zamba2-2.7b": "hybrid",
    "seamless-m4t-large-v2": "encdec",
}


@pytest.fixture(scope="module", params=sorted(FAMILIES))
def family(request):
    arch = request.param
    cfg = R.get_config(arch, reduced=True)
    model = R.build_model(arch, cfg)
    params = model.init(jax.random.key(0))
    return arch, cfg, model, params


def _batch(cfg, rows, seq, seed):
    rng = np.random.default_rng(seed)
    batch = {"tokens": rng.integers(1, cfg.vocab_size, (rows, seq)).astype(np.int32)}
    if cfg.arch_type == "audio":
        batch["src_embeds"] = rng.standard_normal(
            (rows, cfg.n_source_frames, cfg.d_model)).astype(np.float32)
    return batch


def _assert_result_match(arch, got, want, *, exact=None):
    """Compare two GenerationResults (tokens exact, saves per family)."""
    exact = FAMILIES[arch] != "encdec" if exact is None else exact
    np.testing.assert_array_equal(np.asarray(got.tokens),
                                  np.asarray(want.tokens))
    assert sorted(got.saves) == sorted(want.saves)
    for k in want.saves:
        if exact:
            np.testing.assert_array_equal(np.asarray(got.saves[k]),
                                          np.asarray(want.saves[k]))
        else:
            np.testing.assert_allclose(np.asarray(got.saves[k]),
                                       np.asarray(want.saves[k]),
                                       rtol=1e-5, atol=1e-5)


def _solo_through_loop(model, params, graph, batch, n_new, *, num_slots=4,
                       max_len=32, pad_to=None):
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(num_slots, max_len)
    sr = loop.admit(graph, dict(batch), n_new, pad_to=pad_to)
    loop.run_to_completion()
    return sr.result()


# --------------------------------------------------------------- loop parity
def test_interleaved_admission_matches_solo(family):
    """Admissions and retirements around a request must not change its
    results: run an interleaved schedule, compare each request against
    admitting it ALONE into an identical loop (bit-exact for causal
    families) and against the plain solo engine (exact greedy tokens)."""
    arch, cfg, model, params = family
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(4, 32)
    specs = [  # (seq, rows, max_new_tokens)
        ("a", 7, 1, 4),
        ("b", 5, 1, 2),
        ("c", 6, 2, 3),
        ("d", 9, 1, 2),
    ]
    reqs = {
        name: (InterventionGraph(), _batch(cfg, rows, seq, seed), n)
        for seed, (name, seq, rows, n) in enumerate(specs)
    }
    srs = {}
    g, b, n = reqs["a"]
    srs["a"] = loop.admit(g, dict(b), n, request_id="a", pad_to=10)
    loop.step()
    g, b, n = reqs["b"]
    srs["b"] = loop.admit(g, dict(b), n, request_id="b", pad_to=10)
    loop.step()
    g, b, n = reqs["c"]
    srs["c"] = loop.admit(g, dict(b), n, request_id="c", pad_to=10)
    loop.step()  # b retires here (its 2 steps are done)
    assert "b" not in {sr.request_id for sr in loop.resident}
    g, b, n = reqs["d"]  # reuses b's freed slot while a/c still decode
    srs["d"] = loop.admit(g, dict(b), n, request_id="d", pad_to=10)
    loop.run_to_completion()

    for name, (graph, batch, n_new) in reqs.items():
        got = srs[name].result()
        want = _solo_through_loop(model, params, InterventionGraph(),
                                  batch, n_new, pad_to=10)
        _assert_result_match(arch, got, want)
        solo = InferenceEngine(model, params, mode="unrolled")
        res = solo.generate_interleaved(InterventionGraph(), dict(batch),
                                        n_new)
        np.testing.assert_array_equal(np.asarray(got.tokens),
                                      np.asarray(res.tokens))


def test_step_graphs_ride_the_loop_and_stay_isolated():
    """Co-tenant intervention graphs at DIFFERENT local steps share one
    interleaved decode execution; a writer's setter stays confined to its
    slot rows and every request matches its solo-through-loop run."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))

    steer_tok = 7

    def writer_graph():
        # bias the step-0 logits hard toward one token: greedy sampling reads
        # POST-intervention logits, so the decode trajectory must change
        g = InterventionGraph()
        t = g.add("tap_get", site="logits", step=0)
        bias = np.zeros((cfg.vocab_size,), np.float32)
        bias[steer_tok] = 1e4
        c = g.add("constant", bias)
        v = g.add("add", Ref(t.id), Ref(c.id))
        g.add("tap_set", Ref(v.id), site="logits", step=0)
        o = g.add("tap_get", site="logits", step=1)
        g.mark_saved("lg1", g.add("save", Ref(o.id)))
        return g

    def reader_graph():
        g = InterventionGraph()
        for s in range(3):
            t = g.add("tap_get", site="layers.output", layer=1, step=s)
            g.mark_saved(f"acts{s}", g.add("save", Ref(t.id)))
        p = g.add("tap_get", site="embed", step=PREFILL_STEP)
        g.mark_saved("emb", g.add("save", Ref(p.id)))
        return g

    batch_w = _batch(cfg, 1, 6, 0)
    batch_r = _batch(cfg, 1, 8, 1)
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(4, 32)
    sr_r = loop.admit(reader_graph(), dict(batch_r), 3, request_id="r",
                      pad_to=9)
    loop.step()  # reader is at local step 1 when the writer joins at step 0
    sr_w = loop.admit(writer_graph(), dict(batch_w), 2, request_id="w",
                      pad_to=9)
    loop.run_to_completion()

    want_r = _solo_through_loop(model, params, reader_graph(), batch_r, 3,
                                pad_to=9)
    want_w = _solo_through_loop(model, params, writer_graph(), batch_w, 2,
                                pad_to=9)
    _assert_result_match("paper-gpt-small", sr_r.result(), want_r)
    _assert_result_match("paper-gpt-small", sr_w.result(), want_w)
    # prefill saves come back at the request's TRUE width despite pad_to
    assert np.asarray(sr_r.saves["emb"]).shape[1] == 7  # 8 - 1
    # the writer's steering really did apply — step-0 token is forced —
    # while the co-tenant reader decoded unsteered
    assert np.asarray(sr_w.result().tokens)[0, 0] == steer_tok
    assert np.asarray(sr_r.result().tokens)[0, 0] != steer_tok


def test_merged_prefill_admission_parity():
    """Same-boundary arrivals in one bucket share ONE prefill; results and
    save shapes still match solo admissions."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))

    def probe(seq, seed):
        g = InterventionGraph()
        p = g.add("tap_get", site="embed", step=PREFILL_STEP)
        g.mark_saved("emb", g.add("save", Ref(p.id)))
        t = g.add("tap_get", site="logits", step=0)
        g.mark_saved("lg0", g.add("save", Ref(t.id)))
        return g, _batch(cfg, 1, seq, seed)

    g1, b1 = probe(6, 0)
    g2, b2 = probe(9, 1)
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(4, 32)
    sr1, sr2 = loop.admit_group(
        [(g1, b1, 3, "p1"), (g2, b2, 2, "p2")], pad_to=10
    )
    loop.run_to_completion()
    assert np.asarray(sr1.saves["emb"]).shape[1] == 5  # unpadded to 6 - 1
    assert np.asarray(sr2.saves["emb"]).shape[1] == 8
    for sr, (g, b, n) in ((sr1, (probe(6, 0)[0], b1, 3)),
                          (sr2, (probe(9, 1)[0], b2, 2))):
        want = _solo_through_loop(model, params, g, b, n, pad_to=10)
        _assert_result_match("paper-gpt-small", sr.result(), want,
                             exact=False)


# ------------------------------------------------------- admission edge cases
def test_retire_and_admit_same_boundary_reuses_slots():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(3, 32)
    a = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 1,
                   request_id="a")
    b = loop.admit(InterventionGraph(), _batch(cfg, 2, 6, 1), 3,
                   request_id="b")
    assert loop.free_rows() == 0
    retired = loop.step()  # a (max_new_tokens=1) retires on the same step
    assert [sr.request_id for sr in retired] == ["a"]
    assert loop.free_rows() == 1
    c = loop.admit(InterventionGraph(), _batch(cfg, 1, 7, 2), 2,
                   request_id="c")
    assert c.start == a.start  # the freed slot is reused immediately
    loop.run_to_completion()
    want = _solo_through_loop(model, params, InterventionGraph(),
                              _batch(cfg, 1, 7, 2), 2, num_slots=3)
    np.testing.assert_array_equal(np.asarray(c.result().tokens),
                                  np.asarray(want.tokens))


def test_all_slots_busy_fifo_within_bucket():
    """With every slot busy, queued same-bucket requests are admitted in
    submit order as rows free up."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=7,
                              num_slots=2, slot_max_len=32)
    reqs = [Request(graph=InterventionGraph(), batch=_batch(cfg, 1, 6 + i, i),
                    max_new_tokens=2 + i) for i in range(4)]
    tickets = [sched.submit(r) for r in reqs]
    done = sched.drain()
    assert len(done) == 4 and all(t.error is None for t in done)
    starts = [t.start_time for t in tickets]
    assert starts == sorted(starts), "admission must be FIFO within bucket"
    assert starts[2] > starts[0], "later arrivals wait for a free slot"
    for r, t in zip(reqs, tickets):
        solo = InferenceEngine(model, params, mode="unrolled")
        res = solo.generate_interleaved(InterventionGraph(), dict(r.batch),
                                        r.max_new_tokens)
        np.testing.assert_array_equal(t.result["tokens"],
                                      np.asarray(res.tokens))


def test_single_token_prompt_admitted_mid_loop(family):
    """An S == 1 request joins a RUNNING loop via empty-cache rows."""
    arch, cfg, model, params = family
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(3, 16)
    long = loop.admit(InterventionGraph(), _batch(cfg, 1, 6, 0), 4,
                      request_id="long")
    loop.step()
    one = loop.admit(InterventionGraph(), _batch(cfg, 1, 1, 1), 3,
                     request_id="one")
    loop.run_to_completion()
    lm = traced_lm(model, params)
    b1 = _batch(cfg, 1, 1, 1)
    toks = jnp.asarray(b1.pop("tokens"))
    with lm.generate(toks, max_new_tokens=3, **{
        k: jnp.asarray(v) for k, v in b1.items()
    }) as tr:
        pass
    np.testing.assert_array_equal(np.asarray(one.result().tokens),
                                  tr.output_tokens)


def test_single_token_prompt_rejects_prefill_taps_in_loop():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(2, 16)
    g = InterventionGraph()
    t = g.add("tap_get", site="embed", step=PREFILL_STEP)
    g.mark_saved("emb", g.add("save", Ref(t.id)))
    with pytest.raises(GraphValidationError, match="prefill"):
        loop.admit(g, _batch(cfg, 1, 1, 0), 2)
    assert loop.free_rows() == 2  # failed admission must not leak slots


def test_zero_recompiles_across_ten_admission_schedule():
    """After warmup, a 10-admission staggered schedule with varied lengths
    inside ONE bucket performs zero new compiles: the decode step is
    specialized on num_slots, prefills pad to the bucket ceiling, and slot
    scatter/clear reuse their traces."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")

    def run_schedule(loop):
        lens = [9, 12, 15, 10, 14, 11, 13, 9, 15, 12]  # one bucket (8..15)
        ceil = _bucket_ceiling(9, 7)
        assert all(_bucket_ceiling(L, 7) == ceil for L in lens)
        srs = []
        for i, L in enumerate(lens):
            while loop.free_rows() == 0:
                loop.step()
            srs.append(loop.admit(InterventionGraph(), _batch(cfg, 1, L, i),
                                  2 + i % 3, request_id=i, pad_to=ceil))
            loop.step()
        loop.run_to_completion()
        return srs

    run_schedule(engine.start_decode_loop(4, 32))  # warmup: compiles happen
    c0 = engine.stats.compiles
    srs = run_schedule(engine.start_decode_loop(4, 32))
    assert engine.stats.compiles == c0, "steady-state must not retrace"
    # and the results are still right
    solo = InferenceEngine(model, params, mode="unrolled")
    res = solo.generate_interleaved(InterventionGraph(),
                                    _batch(cfg, 1, 15, 2), 4)
    np.testing.assert_array_equal(np.asarray(srs[2].result().tokens),
                                  np.asarray(res.tokens))


# --------------------------------------------------------- scheduler behavior
def test_response_time_reflects_own_span():
    """A short request co-resident with a long one finishes (and reports)
    earlier — per-request latency is its own submit -> retire span, not the
    group/drain span."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=7,
                              num_slots=4, slot_max_len=48)
    short = Request(graph=InterventionGraph(), batch=_batch(cfg, 1, 6, 0),
                    max_new_tokens=2)
    long = Request(graph=InterventionGraph(), batch=_batch(cfg, 1, 7, 1),
                   max_new_tokens=12)
    t_long = sched.submit(long)
    t_short = sched.submit(short)
    sched.drain()
    assert t_short.error is None and t_long.error is None
    assert t_short.finish_time < t_long.finish_time
    assert t_short.response_time < t_long.response_time
    for t in (t_short, t_long):
        assert t.submit_time <= t.start_time <= t.finish_time
        assert t.response_time >= (t.finish_time - t.start_time)
        assert t.queue_wait >= 0


def test_max_batch_cells_splits_groups_and_records():
    """Length-aware sizing: rows x padded-length above the cells cap splits
    a burst group (row cap alone would have merged it) and the decision is
    recorded in EngineStats."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel", pad_slack=16,
                              max_batch_rows=64, max_batch_cells=40)

    def probe(seq, seed):
        g = InterventionGraph()
        t = g.add("tap_get", site="logits")
        g.mark_saved("out", g.add("save", Ref(t.id)))
        return Request(graph=g, batch=_batch(cfg, 1, seq, seed))

    reqs = [probe(14, s) for s in range(4)]  # 4 rows x 14 = 56 > 40
    tickets = [sched.submit(r) for r in reqs]
    sched.drain()
    assert all(t.error is None for t in tickets)
    assert engine.stats.cap_splits_cells > 0
    assert engine.stats.merged_groups >= 2  # split into >= 2 groups
    for r, t in zip(reqs, tickets):
        solo, _ = InferenceEngine(model, params).execute(r.graph, r.batch)
        np.testing.assert_allclose(np.asarray(t.result["out"]),
                                   np.asarray(solo["out"]),
                                   rtol=1e-5, atol=1e-5)


def test_continuous_mixes_gen_and_single_forward():
    """Single-forward traces still burst-merge between decode steps."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=7,
                              num_slots=4, slot_max_len=32)
    g = InterventionGraph()
    t = g.add("tap_get", site="logits")
    g.mark_saved("out", g.add("save", Ref(t.id)))
    gen = Request(graph=InterventionGraph(), batch=_batch(cfg, 1, 6, 0),
                  max_new_tokens=3)
    fwd = Request(graph=g, batch=_batch(cfg, 1, 9, 1))
    t_gen = sched.submit(gen)
    t_fwd = sched.submit(fwd)
    done = sched.drain()
    assert len(done) == 2 and all(t.error is None for t in done)
    assert t_fwd.result["out"].shape == (1, 9, cfg.vocab_size)
    assert t_gen.result["tokens"].shape == (1, 3)


def test_oversized_requests_fall_back_solo():
    """Requests that can never fit the slot table (too many rows, or prompt
    + N beyond the table's max_len) are served by the classic solo path."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=0,
                              num_slots=2, slot_max_len=12)
    wide = Request(graph=InterventionGraph(), batch=_batch(cfg, 3, 6, 0),
                   max_new_tokens=2)   # 3 rows > 2 slots
    deep = Request(graph=InterventionGraph(), batch=_batch(cfg, 1, 10, 1),
                   max_new_tokens=8)   # 9 + 8 > 12 cache positions
    t_w = sched.submit(wide)
    t_d = sched.submit(deep)
    sched.drain()
    assert t_w.error is None and t_w.result["tokens"].shape == (3, 2)
    assert t_d.error is None and t_d.result["tokens"].shape == (1, 8)
    assert engine.stats.admissions == 0  # neither rode the loop


def test_bad_step_graph_rejected_at_admission_not_step_time():
    """A decode-step slice tapping an unknown site must fail ITS ticket at
    admission; co-tenants keep decoding and later drains still work (a
    step-time crash would wedge the shared loop for everyone)."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", pad_slack=7,
                              num_slots=4, slot_max_len=32)
    bad = InterventionGraph()
    bad.add("tap_get", site="never-a-site", step=1)
    t_ok1 = sched.submit(Request(graph=InterventionGraph(),
                                 batch=_batch(cfg, 1, 6, 0),
                                 max_new_tokens=3))
    t_bad = sched.submit(Request(graph=bad, batch=_batch(cfg, 1, 7, 1),
                                 max_new_tokens=3))
    done = sched.drain()
    assert t_bad.error is not None and "never-a-site" in t_bad.error
    assert t_ok1.error is None and t_ok1.result["tokens"].shape == (1, 3)
    # the loop is NOT wedged: a later drain serves normally
    t_ok2 = sched.submit(Request(graph=InterventionGraph(),
                                 batch=_batch(cfg, 1, 6, 2),
                                 max_new_tokens=2))
    sched.drain()
    assert t_ok2.error is None and t_ok2.result["tokens"].shape == (1, 2)
    assert len(done) == 2


def test_step_time_failure_evicts_only_offender():
    """Failures that admission validation cannot catch (a shape-mismatched
    setter value) evict the offending request mid-loop; the co-tenant's
    results are unaffected and bit-exact vs running alone."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    bad = InterventionGraph()
    t = bad.add("tap_get", site="logits", step=1)
    c = bad.add("constant", np.zeros((7, 3), np.float32))
    v = bad.add("add", Ref(t.id), Ref(c.id))  # broadcast error at step 1
    bad.mark_saved("boom", bad.add("save", Ref(v.id)))
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(4, 32)
    good_batch = _batch(cfg, 1, 6, 0)
    sr_good = loop.admit(InterventionGraph(), dict(good_batch), 4,
                         request_id="good", pad_to=8)
    sr_bad = loop.admit(bad, _batch(cfg, 1, 7, 1), 3, request_id="bad",
                        pad_to=8)
    done = loop.run_to_completion()
    assert sr_bad in done and sr_bad.error is not None
    with pytest.raises(RuntimeError, match="evicted"):
        sr_bad.result()
    assert sr_good.error is None
    want = _solo_through_loop(model, params, InterventionGraph(),
                              good_batch, 4, pad_to=8)
    _assert_result_match("paper-gpt-small", sr_good.result(), want)


def test_log_isolation_between_co_tenants():
    """A request's logs contain only ITS OWN logged values (request-local
    shapes), never a co-tenant's."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))

    def logging_graph(step):
        g = InterventionGraph()
        t = g.add("tap_get", site="logits", step=step)
        g.add("log", Ref(t.id))
        return g

    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(4, 32)
    a = loop.admit(logging_graph(0), _batch(cfg, 1, 6, 0), 2,
                   request_id="a", pad_to=8)
    b = loop.admit(logging_graph(0), _batch(cfg, 2, 7, 1), 2,
                   request_id="b", pad_to=8)
    loop.run_to_completion()
    assert len(a.logs) == 1 and len(b.logs) == 1
    assert np.asarray(a.logs[0][1]).shape == (1, 1, cfg.vocab_size)
    assert np.asarray(b.logs[0][1]).shape == (2, 1, cfg.vocab_size)


def test_cotenant_log_isolation_rides_compiled_path():
    """A log()-instrumented request co-resident with a CLEAN request must
    not push the shared slot table off the fused path: zero eager steps,
    the island compiles, logs land only on the logging tenant, and the
    clean tenant's tokens/saves are bit-exact vs running alone."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    n_new = 4

    def logger_graph():
        g = InterventionGraph()
        for s in range(n_new):
            t = g.add("tap_get", site="logits", step=s)
            m = g.add("jnp.mean", Ref(t.id), step=s)
            g.add("log", Ref(m.id), step=s)
        return g

    def clean_graph():
        g = InterventionGraph()
        for s in range(n_new):
            t = g.add("tap_get", site="logits", step=s)
            g.mark_saved("lg", g.add("save", Ref(t.id), step=s))
        return g

    batch_l = _batch(cfg, 1, 6, 0)
    batch_c = _batch(cfg, 1, 7, 1)
    engine = InferenceEngine(model, params, mode="unrolled")
    loop = engine.start_decode_loop(2, 32)
    sr_l = loop.admit(logger_graph(), dict(batch_l), n_new,
                      request_id="log", pad_to=8)
    sr_c = loop.admit(clean_graph(), dict(batch_c), n_new,
                      request_id="clean", pad_to=8)
    loop.run_to_completion()
    assert engine.stats.eager_steps == 0, \
        "log co-tenancy must not fall back to the eager interpreter"
    assert engine.stats.islands_compiled >= 1
    # logs are attributed to the logging tenant only
    assert len(sr_l.logs) == n_new
    assert sr_c.logs == []
    # the clean tenant is bit-exact vs riding the loop alone
    want_c = _solo_through_loop(model, params, clean_graph(), batch_c,
                                n_new, num_slots=2, pad_to=8)
    _assert_result_match("paper-gpt-small", sr_c.result(), want_c)
    # and the logged values are the tenant's OWN row slice, not the table's
    want_l = _solo_through_loop(model, params, logger_graph(), batch_l,
                                n_new, num_slots=2, pad_to=8)
    assert len(want_l.logs) == n_new
    for (_, got), (_, want) in zip(sr_l.logs, want_l.logs):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_grad_generation_request_served_fused_solo():
    """A .grad generation request through the scheduler is served by the
    solo fallback, which now compiles the grad step into the fused scan —
    the ticket carries the gradient save and greedy tokens."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", num_slots=2,
                              slot_max_len=16)
    g = InterventionGraph()
    gg = g.add("grad_get", site="layers.mlp.output", layer=1, step=1)
    g.mark_saved("g", g.add("save", Ref(gg.id), step=1))
    t = g.add("tap_get", site="logits", step=1)
    sq = g.add("mul", Ref(t.id), Ref(t.id), step=1)
    loss = g.add("jnp.sum", Ref(sq.id), step=1)
    g.backward_loss = loss.id
    grad_req = Request(graph=g, batch=_batch(cfg, 1, 5, 0),
                       max_new_tokens=2)
    ok = Request(graph=InterventionGraph(), batch=_batch(cfg, 1, 5, 1),
                 max_new_tokens=2)
    t_grad = sched.submit(grad_req)
    t_ok = sched.submit(ok)
    sched.drain()
    assert t_grad.error is None, t_grad.error
    assert t_grad.result["tokens"].shape == (1, 2)
    assert np.any(np.asarray(t_grad.result["g"]))  # gradient flowed
    assert t_ok.error is None and t_ok.result["tokens"].shape == (1, 2)


def test_grad_generation_without_loss_errors_cleanly():
    """A grad_get with NO declared backward loss is a per-request error —
    the co-tenant keeps its results."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, mode="unrolled")
    sched = CoTenantScheduler(engine, policy="continuous", num_slots=2,
                              slot_max_len=16)
    g = InterventionGraph()
    g.add("grad_get", site="logits", step=0)
    bad = Request(graph=g, batch=_batch(cfg, 1, 5, 0), max_new_tokens=2)
    ok = Request(graph=InterventionGraph(), batch=_batch(cfg, 1, 5, 1),
                 max_new_tokens=2)
    t_bad = sched.submit(bad)
    t_ok = sched.submit(ok)
    sched.drain()
    assert t_bad.error is not None
    assert t_ok.error is None and t_ok.result["tokens"].shape == (1, 2)


# ------------------------------------------------------------ remote tracing
def test_remote_generate_tracer_roundtrip():
    """GenerateTracer(remote=True): the step graph ships over the wire,
    steering applies server-side, stacked saves come back — identical to
    the local trace."""
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    server = NDIFServer()
    server.host(cfg.name, model, params, policy="continuous")
    transport = LoopbackTransport(server.handle)
    client = NDIFClient(transport, cfg.name)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab_size, (2, 7)).astype(np.int32)

    def run(lm, remote):
        with lm.generate(toks, max_new_tokens=4, remote=remote) as tr:
            with tr.prefill():
                lm.embed.save("emb")
            for _ in tr.steps():
                lm.layers[1].output += np.float32(0.5)
                lm.logits.save("lg")
        return tr

    sent0 = transport.stats.bytes_sent
    tr_r = run(traced_lm(model, None, backend=client), True)
    assert transport.stats.bytes_sent > sent0  # actually went over the wire
    tr_l = run(traced_lm(model, params), False)
    np.testing.assert_array_equal(tr_r.output_tokens, tr_l.output_tokens)
    assert np.asarray(tr_r.result("lg")).shape == (2, 4, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(tr_r.result("lg")),
                               np.asarray(tr_l.result("lg")),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(tr_r.result("emb")),
                               np.asarray(tr_l.result("emb")),
                               rtol=1e-5, atol=1e-5)
    stats = client.stats()
    assert stats["admissions"] >= 1 and stats["retires"] >= 1
    assert 0.0 < stats["slot_occupancy"] <= 1.0
    # the islands_compiled counter rides the same snapshot: the steered +
    # save-carrying step graph compiles (no island here, counter just
    # present and non-negative)
    assert stats["islands_compiled"] >= 0
    # the paged-pool counters ride the same wire snapshot: the serving
    # loop is paged by default, and everything retired above
    assert stats["page_allocs"] >= 1 and stats["page_frees"] >= 1
    assert stats["pages_in_use"] == 0 and stats["pages_free"] >= 1
    assert stats["alloc_retries"] == 0


def test_remote_generate_requires_backend():
    cfg = R.get_config("paper-gpt-small", reduced=True)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    lm = traced_lm(model, params)
    with pytest.raises(RuntimeError, match="backend"):
        with lm.generate(np.ones((1, 4), np.int32), 2, remote=True) as tr:
            pass


# ------------------------------------------------------------------ unit bits
def test_admit_key_buckets_and_exclusions():
    cfg = R.get_config("paper-gpt-small", reduced=True)

    def req(seq, n=2, rows=1):
        return Request(graph=InterventionGraph(),
                       batch=_batch(cfg, rows, seq, 0), max_new_tokens=n)

    # max_new_tokens is NOT part of the admission key (independent retire)
    assert _admit_key(req(9, n=2), 7) == _admit_key(req(12, n=30), 7)
    assert _admit_key(req(9), 7) != _admit_key(req(17), 7)  # other bucket
    assert _admit_key(req(1), 7) is None  # S == 1 admits alone
    g = InterventionGraph()
    g.add("grad_get", site="logits", step=0)
    assert _admit_key(Request(graph=g, batch=_batch(cfg, 1, 5, 0),
                              max_new_tokens=2), 7) is None


def test_uniform_solo_generation_stays_lengths_free():
    """A uniform, unpadded solo generation must not synthesize per-row
    lengths: paths gated on ragged masking (sliding-window prefill beyond
    the window, the pallas guard) worked before the DecodeLoop refactor and
    must keep working."""
    from repro.core.generation import run_generation

    cfg = R.get_config("paper-gpt-small", reduced=True, sliding_window=8)
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    toks = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (1, 12)).astype(np.int32)
    # padded prompt exceeds the window: the ragged+window guard would raise
    # if admission injected a lengths array for this uniform prompt
    res = run_generation(model, params, InterventionGraph(),
                         jnp.asarray(toks), 2, mode="unrolled",
                         cache_kind="window")
    assert np.asarray(res.tokens).shape == (1, 2)


def test_bucket_ceiling():
    assert _bucket_ceiling(9, 7) == 15
    assert _bucket_ceiling(15, 7) == 15
    assert _bucket_ceiling(16, 7) == 23
    assert _bucket_ceiling(6, 0) == 6  # slack 0: exact widths
