"""Tracer/Envoy API edges, op registry, update_path semantics."""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._prop import given, settings, st

from repro.core.op_registry import OPS, apply_path, register_op, update_path
from repro.core.tracer import Session


class TestEnvoy:
    def test_unknown_path_raises(self, tiny, x2x4):
        with tiny.trace(x2x4) as tr:
            tr._deferred = True
            with pytest.raises(AttributeError, match="no tap site"):
                tiny.layers[0].bogus

    def test_per_layer_site_without_index_rejected(self, tiny, x2x4):
        from repro.core.graph import GraphValidationError

        with pytest.raises(GraphValidationError, match="unknown site"):
            with tiny.trace(x2x4):
                # layers.output requires a [layer] index — layer=None is not
                # in the schedule and must be caught at validation.
                tiny.layers.output.save("x")

    def test_access_outside_trace_raises(self, tiny):
        with pytest.raises(RuntimeError, match="inside a trace context"):
            tiny.layers

    def test_exception_in_context_skips_execution(self, tiny, x2x4):
        with pytest.raises(ValueError, match="boom"):
            with tiny.trace(x2x4) as tr:
                tiny.output.save("x")
                raise ValueError("boom")
        with pytest.raises(RuntimeError):
            tr.result("x")

    def test_value_before_execution_raises(self, tiny, x2x4):
        with pytest.raises(RuntimeError):
            with tiny.trace(x2x4):
                v = tiny.output.save("v")
                _ = v.value  # context not exited yet

    def test_save_auto_names_unique(self, tiny, x2x4):
        with tiny.trace(x2x4) as tr:
            a = tiny.layers[0].output.save()
            b = tiny.layers[1].output.save()
        assert not np.allclose(np.asarray(a.value), np.asarray(b.value))


class TestSessionLocal:
    def test_local_session_runs_on_exit(self, tiny, x2x4):
        with tiny.session() as sess:
            with sess.trace(x2x4) as t1:
                t1_out = tiny.output.save("o")
            with pytest.raises(RuntimeError):
                t1.result("o")  # deferred until session exit
            with sess.trace(2 * x2x4) as t2:
                t2_out = tiny.output.save("o")
        a = np.asarray(t1.result("o"))
        b = np.asarray(t2.result("o"))
        np.testing.assert_allclose(2 * a, b, rtol=1e-6)

    def test_trace_outside_session_raises(self, tiny, x2x4):
        sess = Session(tiny, remote=False, backend=None)
        with pytest.raises(RuntimeError, match="not active"):
            sess.trace(x2x4)


class TestOpRegistry:
    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_op("add", lambda a, b: a + b)

    def test_core_ops_present(self):
        for name in ("add", "mul", "getitem", "update_path", "softmax",
                     "jnp.sum", "logit_diff", "nll", "topk"):
            assert name in OPS

    def test_update_path_array(self):
        x = jnp.zeros((3, 4))
        y = update_path(x, ((1, slice(0, 2)),), 7.0)
        assert float(y[1, 0]) == 7.0 and float(y[1, 2]) == 0.0
        assert float(x[1, 0]) == 0.0  # functional

    def test_update_path_tuple(self):
        x = (jnp.zeros((2,)), jnp.ones((2,)))
        y = update_path(x, (0, (1,)), 5.0)
        assert float(y[0][1]) == 5.0
        assert float(y[1][0]) == 1.0

    def test_apply_path(self):
        x = (jnp.arange(6).reshape(2, 3),)
        assert int(apply_path(x, (0, (1, 2)))) == 5


@given(
    st.integers(0, 2),
    st.integers(0, 3),
    st.floats(-10, 10, width=32),
)
@settings(max_examples=30, deadline=None)
def test_property_update_path_roundtrip(i, j, val):
    x = jnp.zeros((3, 4))
    y = update_path(x, ((i, j),), np.float32(val))
    assert float(apply_path(y, ((i, j),))) == pytest.approx(float(np.float32(val)))
    # everything else untouched
    mask = np.ones((3, 4), bool)
    mask[i, j] = False
    assert np.all(np.asarray(y)[mask] == 0)


def test_engine_generate_matches_forward_argmax():
    import jax

    from repro.models import registry as R
    from repro.serving.engine import InferenceEngine

    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params)
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    gen, _ = engine.generate(jnp.asarray(toks), max_new_tokens=3)
    # greedy decode step 1 == argmax of the teacher-forcing forward
    full = model.forward(params, {"tokens": jnp.asarray(toks)})["logits"]
    np.testing.assert_array_equal(gen[:, 0], np.argmax(np.asarray(full)[:, -1], -1))
