"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family variant (≤2 layers,
d_model≤512, ≤4 experts) and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs.  Decode paths are smoked
for every family that has one.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # full model zoo: minutes on CPU (pytest.ini)

from repro.data.pipeline import DataConfig, synthetic_lm_data
from repro.models import registry as R
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import make_train_step

ARCHS = R.list_archs()


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
    }
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (B, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    if cfg.arch_type == "audio":
        batch["src_embeds"] = rng.standard_normal(
            (B, cfg.n_source_frames, cfg.d_model)).astype(np.float32)
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    arch = request.param
    cfg = R.get_config(arch, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    model = R.build_model(arch, cfg)
    params = model.init(jax.random.key(0))
    return arch, cfg, model, params


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = _batch(cfg)
    out = model.forward(params, {k: v for k, v in batch.items()
                                 if k != "labels"}, mode="scan")
    assert out["logits"].shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(out["logits"]).any()), f"{arch}: NaN logits"


def test_scan_equals_unrolled(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    a = model.forward(params, batch, mode="scan")["logits"]
    b = model.forward(params, batch, mode="unrolled")["logits"]
    np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)


def test_one_train_step(arch_setup):
    arch, cfg, model, params = arch_setup
    init_state, step = make_train_step(
        model, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10),
        mode="scan",
    )
    state = init_state(params)
    state, metrics = jax.jit(step)(state, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


def test_decode_consistency(arch_setup):
    """prefill(S-1) + decode(1) == forward(S) last-position logits."""
    arch, cfg, model, params = arch_setup
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    tokens = batch["tokens"]
    full = model.forward(params, batch, mode="scan")["logits"]
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :-1]
    _, cache = model.prefill(params, pre_batch, max_len=tokens.shape[1])
    step_out, _ = model.decode_step(
        params, cache,
        {"token": tokens[:, -1:],
         "pos": jnp.full((tokens.shape[0],), tokens.shape[1] - 1, jnp.int32)},
        mode="scan",
    )
    np.testing.assert_allclose(
        step_out["logits"][:, 0], full[:, -1], rtol=2e-3, atol=2e-3
    )


def test_remat_forward_matches(arch_setup):
    arch, cfg, model, params = arch_setup
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    a = model.forward(params, batch, mode="scan")["logits"]
    b = model.forward(params, batch, mode="scan", remat=True)["logits"]
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_full_config_matches_assignment():
    """The FULL configs carry exactly the assigned hyperparameters."""
    expect = {
        "minicpm3-4b": dict(n_layers=62, d_model=2560, n_heads=40,
                            n_kv_heads=40, d_ff=6400, vocab_size=73448),
        "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                     n_kv_heads=8, vocab_size=32064,
                                     n_experts=16, top_k=2),
        "internlm2-20b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab_size=92544),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab_size=32000,
                            ssm_state=64),
        "qwen1.5-110b": dict(n_layers=80, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=49152, vocab_size=152064,
                             qkv_bias=True),
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, vocab_size=50280,
                            ssm_state=128),
        "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, n_heads=16,
                                      n_kv_heads=16, d_ff=8192,
                                      vocab_size=256206),
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                                  n_kv_heads=4, vocab_size=151936,
                                  n_experts=128, top_k=8),
        "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=28672,
                                     vocab_size=128256),
        "qwen3-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
                         d_ff=12288, vocab_size=151936, qk_norm=True),
    }
    for arch, fields in expect.items():
        cfg = R.get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
        assert cfg.source, f"{arch} missing source citation"


def test_interventions_on_reduced_arch():
    """The paper's technique composes with every family: patch + save on a
    reduced config via the tracing API (dense + ssm + moe exemplars)."""
    from repro.models.traced import traced_lm

    for arch in ["qwen3-8b", "mamba2-1.3b", "qwen3-moe-30b-a3b"]:
        cfg = R.get_config(arch, reduced=True)
        model = R.build_model(arch, cfg)
        params = model.init(jax.random.key(0))
        lm = traced_lm(model, params, mode="unrolled")
        toks = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 8)).astype(np.int32)
        with lm.trace(jnp.asarray(toks)):
            lm.layers[1].output[1, :, :] = lm.layers[1].output[0, :, :]
            out = lm.output.save("out")
        assert np.asarray(out.value).shape == (2, 8, cfg.vocab_size)
        assert np.isfinite(np.asarray(out.value)).all()
