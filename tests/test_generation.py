"""Intervention-aware generation: step graphs, the generate tracer, the
cached compiled decode path, and generation batch-merging.

Covers the PR-1 acceptance criteria:
  * ``with lm.generate(tokens, max_new_tokens=8) as tr`` can set
    ``lm.layers[k].mlp.output`` at decode steps and ``.save()`` per-step
    logits stacked as ``(B, 8, V)``;
  * intervened generation matches an unrolled per-step reference built from
    the seed machinery (``run_interleaved`` over ``decode_step``);
  * a second identical ``generate()`` performs ZERO new compiles;
  * ``max_new_tokens=1`` returns the same logits shape as any other N.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.generation import run_generation, slice_steps
from repro.core.graph import (
    ALL_STEPS,
    PREFILL_STEP,
    GraphValidationError,
    InterventionGraph,
    Ref,
    assign_steps,
)
from repro.core.interleave import run_interleaved
from repro.core.serialize import loads, dumps
from repro.models import registry as R
from repro.models.traced import traced_lm
from repro.serving.engine import InferenceEngine
from repro.serving.scheduler import CoTenantScheduler, Request


@pytest.fixture(scope="module")
def gpt():
    cfg = R.get_config("paper-gpt-small")
    model = R.build_model("paper-gpt-small", cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 6)).astype(np.int32))
    return cfg, model, params, toks


# --------------------------------------------------------------- step graphs
def _step_graph(n_steps=3, site="layers.mlp.output", layer=1):
    g = InterventionGraph()
    for s in range(n_steps):
        t = g.add("tap_get", site=site, layer=layer, step=s)
        sv = g.add("save", Ref(t.id))
        g.mark_saved(f"acts@step{s}", sv)
    return g


def test_assign_steps_basic():
    g = _step_graph(3)
    ready = assign_steps(g, 3)
    assert ready[0] == 0 and ready[2] == 1 and ready[4] == 2


def test_assign_steps_rejects_unstepped_tap():
    g = InterventionGraph()
    g.add("tap_get", site="logits")
    with pytest.raises(GraphValidationError, match="no step"):
        assign_steps(g, 2)


def test_assign_steps_rejects_out_of_range():
    g = InterventionGraph()
    g.add("tap_get", site="logits", step=5)
    with pytest.raises(GraphValidationError, match="outside"):
        assign_steps(g, 2)


def test_assign_steps_rejects_backwards_write():
    """A setter at step 0 may not consume a value read at step 2."""
    g = InterventionGraph()
    t = g.add("tap_get", site="logits", step=2)
    g.add("tap_set", Ref(t.id), site="logits", step=0)
    with pytest.raises(GraphValidationError, match="backwards"):
        assign_steps(g, 3)


def test_assign_steps_rejects_broadcast_save():
    g = InterventionGraph()
    t = g.add("tap_get", site="logits", step=ALL_STEPS)
    sv = g.add("save", Ref(t.id))
    g.mark_saved("x", sv)
    with pytest.raises(GraphValidationError, match="all_steps"):
        assign_steps(g, 3)


def test_slice_steps_cross_step_flow():
    """A value read at step 0 and written at step 2 crosses the env."""
    g = InterventionGraph()
    t0 = g.add("tap_get", site="logits", step=0)
    g.add("tap_set", Ref(t0.id), site="logits", step=2)
    slices = slice_steps(g, 3)
    assert set(slices) == {0, 2}
    assert slices[0].exports and slices[2].imports
    assert list(slices[2].imports.values()) == [t0.id]


def test_step_survives_wire_format():
    g = _step_graph(2)
    g2 = loads(dumps(g))
    assert [n.step for n in g2.nodes] == [n.step for n in g.nodes]


# ------------------------------------------------------------ tracer e2e
def test_generate_stacked_logits_shape(gpt):
    cfg, model, params, toks = gpt
    lm = traced_lm(model, params)
    N = 8
    with lm.generate(toks, max_new_tokens=N) as tr:
        for _ in tr.steps():
            lm.logits.save("logits")
    assert np.asarray(tr.result("logits")).shape == (2, N, cfg.vocab_size)
    assert tr.output_tokens.shape == (2, N)


def test_generate_matches_plain_engine(gpt):
    """No interventions -> identical tokens to the engine's decode loop."""
    cfg, model, params, toks = gpt
    lm = traced_lm(model, params)
    with lm.generate(toks, max_new_tokens=5) as tr:
        for _ in tr.steps():
            lm.logits.save("lg")
    engine = InferenceEngine(model, params)
    gen, logits = engine.generate(toks, max_new_tokens=5)
    np.testing.assert_array_equal(tr.output_tokens, gen)
    np.testing.assert_allclose(
        np.asarray(tr.result("lg"))[:, -1:], logits, rtol=1e-5, atol=1e-5)


def test_steered_generation_matches_unrolled_reference(gpt):
    """Intervened decode == a manual per-step loop over decode_step with the
    same intervention applied via the seed interleaver (run_interleaved)."""
    cfg, model, params, toks = gpt
    N, k, delta = 4, 1, 7.5
    lm = traced_lm(model, params)
    with lm.generate(toks, max_new_tokens=N) as tr:
        with tr.step(2):
            lm.layers[k].mlp.output += delta
        for _ in tr.steps():
            lm.logits.save("lg")

    # ---- reference: hand-rolled loop using only seed machinery ----
    B, S = toks.shape
    out, cache = model.prefill(
        params, {"tokens": toks[:, :-1]}, mode="unrolled", max_len=S - 1 + N
    )
    sched = model.site_schedule("unrolled")
    token = toks[:, -1:]
    ref_tokens, ref_logits = [], []
    for t in range(N):
        pos = jnp.full((B,), S - 1 + t, jnp.int32)
        if t == 2:
            g = InterventionGraph()
            tap = g.add("tap_get", site="layers.mlp.output", layer=k)
            c = g.add("constant", np.float32(delta))
            u = g.add("add", Ref(tap.id), Ref(c.id))
            g.add("tap_set", Ref(u.id), site="layers.mlp.output", layer=k)
            (o, cache), _, _ = run_interleaved(
                lambda p_, c_, tk, ps: model.decode_step(
                    p_, c_, {"token": tk, "pos": ps}, mode="unrolled"),
                g, sched, (params, cache, token, pos), {},
            )
        else:
            o, cache = model.decode_step(
                params, cache, {"token": token, "pos": pos}, mode="unrolled")
        logits = o["logits"]
        token = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        ref_tokens.append(np.asarray(token[:, 0]))
        ref_logits.append(np.asarray(logits))

    np.testing.assert_array_equal(
        tr.output_tokens, np.stack(ref_tokens, axis=1))
    np.testing.assert_allclose(
        np.asarray(tr.result("lg")),
        np.concatenate(ref_logits, axis=1), rtol=1e-5, atol=1e-5)


def test_broadcast_setter_equals_per_step(gpt):
    cfg, model, params, toks = gpt
    lm = traced_lm(model, params)
    with lm.generate(toks, max_new_tokens=3) as t_all:
        with t_all.all_steps():
            lm.layers[1].mlp.output += 10.0
    with lm.generate(toks, max_new_tokens=3) as t_each:
        for _ in t_each.steps():
            lm.layers[1].mlp.output += 10.0
    np.testing.assert_array_equal(t_all.output_tokens, t_each.output_tokens)


def test_prefill_taps_fire_in_generation(gpt):
    cfg, model, params, toks = gpt
    lm = traced_lm(model, params)
    with lm.generate(toks, max_new_tokens=2) as tr:
        with tr.prefill():
            lm.embed.save("emb")
    # prompt prefill runs on tokens[:, :-1]
    assert np.asarray(tr.result("emb")).shape == (2, 5, cfg.d_model)


def test_generate_scan_mode_matches_unrolled(gpt):
    cfg, model, params, toks = gpt
    results = {}
    for mode in ("unrolled", "scan"):
        lm = traced_lm(model, params, mode=mode)
        with lm.generate(toks, max_new_tokens=4) as tr:
            with tr.step(1):
                lm.layers[2].mlp.output += 5.0
            for _ in tr.steps():
                lm.logits.save("lg")
        results[mode] = tr
    np.testing.assert_array_equal(
        results["scan"].output_tokens, results["unrolled"].output_tokens)
    np.testing.assert_allclose(
        np.asarray(results["scan"].result("lg")),
        np.asarray(results["unrolled"].result("lg")),
        rtol=2e-4, atol=2e-4)


def test_steps_break_restores_default_pointer(gpt):
    """Breaking out of tr.steps() must not leave later taps on the break
    step (regression: generator finally-clause)."""
    cfg, model, params, toks = gpt
    lm = traced_lm(model, params)
    with lm.generate(toks, max_new_tokens=4) as tr:
        for s in tr.steps():
            if s == 2:
                break
        lm.logits.save("lg")  # default pointer -> step 0
    assert "lg@step0" in tr.graph.saves


def test_steps_nested_in_prefill_restores_enclosing_pointer(gpt):
    """steps() inside prefill() must hand the PREFILL pointer back."""
    cfg, model, params, toks = gpt
    lm = traced_lm(model, params)
    with lm.generate(toks, max_new_tokens=3) as tr:
        with tr.prefill():
            for _ in tr.steps(0, 2):
                lm.logits.save("per_step")
            lm.embed.save("emb")  # still the prefill phase
    assert f"emb@step{PREFILL_STEP}" in tr.graph.saves
    assert np.asarray(tr.result("emb")).shape == (2, 5, cfg.d_model)


def test_mixed_prefill_and_step_save_rejected(gpt):
    """Prefill saves are prompt-shaped and cannot stack with per-step
    saves under one name — must fail loudly at trace time."""
    cfg, model, params, toks = gpt
    lm = traced_lm(model, params)
    with pytest.raises(GraphValidationError, match="prefill"):
        with lm.generate(toks, max_new_tokens=2) as tr:
            with tr.prefill():
                lm.logits.save("lg")
            for _ in tr.steps():
                lm.logits.save("lg")


def test_reserved_result_keys_win_over_saves(gpt):
    """A user save named 'logits' must not clobber the generated output."""
    cfg, model, params, toks = gpt
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="sequential")
    g = InterventionGraph()
    t = g.add("tap_get", site="layers.mlp.output", layer=0, step=0)
    g.mark_saved("logits", g.add("save", Ref(t.id)))
    ticket = sched.submit(Request(
        graph=g, batch={"tokens": np.asarray(toks)}, max_new_tokens=2))
    sched.drain()
    assert ticket.error is None
    assert ticket.result["tokens"].shape == (2, 2)
    # "logits" is the reserved generated output, not the (B,1,d) save
    assert ticket.result["logits"].shape == (2, 1, cfg.vocab_size)


def test_generate_requires_zoo_model(tiny=None):
    from tests.conftest import make_tiny_model

    lm = make_tiny_model()
    with pytest.raises(RuntimeError, match="traced_lm"):
        with lm.generate(jnp.zeros((1, 4), jnp.int32), max_new_tokens=2):
            pass


def test_ssm_state_tap_during_decode():
    """Attention-free family: the recurrent state is steerable per step."""
    cfg = R.get_config("mamba2-1.3b", reduced=True)
    model = R.build_model("mamba2-1.3b", cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 5)).astype(np.int32))
    lm = traced_lm(model, params)
    with lm.generate(toks, max_new_tokens=3) as tr:
        for _ in tr.steps():
            lm.layers[0].ssm_state.save("state")
    st = np.asarray(tr.result("state"))
    # per-step states stacked on a new leading axis (no token axis)
    assert st.shape[0] == 3


# -------------------------------------------------------- engine fast path
def test_engine_generate_zero_recompiles(gpt):
    cfg, model, params, toks = gpt
    engine = InferenceEngine(model, params)
    engine.generate(toks, max_new_tokens=4)
    c0 = engine.stats.compiles
    assert c0 > 0
    gen2, _ = engine.generate(toks, max_new_tokens=4)
    assert engine.stats.compiles == c0, "second generate() must not retrace"
    # a LONGER generation reuses the same decode executable only if shapes
    # match; same max_new_tokens with new content stays cached too
    toks2 = (toks + 1) % cfg.vocab_size
    engine.generate(toks2, max_new_tokens=4)
    assert engine.stats.compiles == c0


def test_engine_generate_single_token_prompt(gpt):
    """S == 1 prompts decode from a directly-initialized empty cache (the
    whole prompt is decoded as step 0); only prefill() taps need S >= 2."""
    cfg, model, params, toks = gpt
    engine = InferenceEngine(model, params)
    gen, logits = engine.generate(toks[:, :1], max_new_tokens=3)
    assert gen.shape == (2, 3) and logits.shape == (2, 1, cfg.vocab_size)
    # first token == argmax of the single-token forward
    full = model.forward(params, {"tokens": toks[:, :1]})["logits"]
    np.testing.assert_array_equal(
        gen[:, 0], np.argmax(np.asarray(full)[:, -1], -1))


def test_engine_generate_shape_consistent_for_n1(gpt):
    cfg, model, params, toks = gpt
    engine = InferenceEngine(model, params)
    gen1, logits1 = engine.generate(toks, max_new_tokens=1)
    gen3, logits3 = engine.generate(toks, max_new_tokens=3)
    assert gen1.shape == (2, 1) and gen3.shape == (2, 3)
    assert logits1.shape == logits3.shape == (2, 1, cfg.vocab_size)
    # N=1 logits are the (post-cache) last-prompt-position logits
    np.testing.assert_array_equal(gen1[:, 0], gen3[:, 0])


def test_engine_generate_interleaved_counts(gpt):
    cfg, model, params, toks = gpt
    engine = InferenceEngine(model, params)
    g = _step_graph(2, site="logits", layer=None)
    res = engine.generate_interleaved(g, {"tokens": toks}, 3)
    assert res.tokens.shape == (2, 3)
    assert set(res.saves) == {"acts@step0", "acts@step1"}
    assert engine.stats.generations == 1
    assert engine.stats.gen_tokens == 6


# --------------------------------------------------- scheduler + serving
def _gen_request(cfg, rows, n_new, seed=0, graph=None):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (rows, 6)).astype(np.int32)
    return Request(graph=graph or InterventionGraph(),
                   batch={"tokens": toks}, max_new_tokens=n_new)


def test_scheduler_merges_generation_requests(gpt):
    cfg, model, params, toks = gpt
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel")
    reqs = [_gen_request(cfg, rows=1 + i % 2, n_new=3, seed=i)
            for i in range(3)]
    tickets = [sched.submit(r) for r in reqs]
    sched.drain()
    assert engine.stats.generations == 1, "compatible gen requests merge"
    for i, (r, t) in enumerate(zip(reqs, tickets)):
        assert t.error is None
        assert t.result["tokens"].shape == (1 + i % 2, 3)
        # isolation: merged output rows == solo run of the same request
        solo_engine = InferenceEngine(model, params)
        gen, _ = solo_engine.generate(
            jnp.asarray(r.batch["tokens"]), max_new_tokens=3)
        np.testing.assert_array_equal(t.result["tokens"], gen)


def test_scheduler_does_not_merge_mismatched_step_counts(gpt):
    cfg, model, params, toks = gpt
    engine = InferenceEngine(model, params)
    sched = CoTenantScheduler(engine, policy="parallel")
    sched.submit(_gen_request(cfg, 1, n_new=2, seed=0))
    sched.submit(_gen_request(cfg, 1, n_new=4, seed=1))
    done = sched.drain()
    assert engine.stats.generations == 2
    assert done[0].result["tokens"].shape == (1, 2)
    assert done[1].result["tokens"].shape == (1, 4)


def test_server_generate_with_graph_roundtrip(gpt):
    from repro.serving import LoopbackTransport, NDIFClient, NDIFServer

    cfg, model, params, toks = gpt
    server = NDIFServer()
    server.host("paper-gpt-small", model, params)
    client = NDIFClient(LoopbackTransport(server.handle), "paper-gpt-small")
    g = _step_graph(2, site="logits", layer=None)
    res = client.generate(np.asarray(toks), max_new_tokens=3, graph=g)
    assert res["tokens"].shape == (2, 3)
    assert res["acts@step0"].shape == (2, 1, cfg.vocab_size)
    # plain generation still round-trips through the scheduler
    res2 = client.generate(np.asarray(toks), max_new_tokens=3)
    np.testing.assert_array_equal(res["tokens"], res2["tokens"])
